import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver:
  1. builds the production mesh ((16,16) single-pod / (2,16,16) multi-pod),
  2. abstractly initializes params/opt-state/decode-state (ShapeDtypeStruct,
     zero allocation),
  3. lowers + compiles the full train_step (fwd + bwd + AdamW update) or
     serve_step (one cached decode token) under FSDP+TP shardings,
  4. records memory_analysis(), cost_analysis(), and the collective bytes
     parsed from the partitioned HLO,
  5. writes results/dryrun/<arch>__<shape>__<mesh>.json, consumed by
     benchmarks/roofline.py and EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch yi-6b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out results/dryrun]
"""

import argparse
import json
import math
import re
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, SHAPES, get_config
from repro.distributed import sharding as shd
from repro.launch import costmodel
from repro.launch.mesh import make_production_mesh
from repro.models import get_model
from repro.train.trainer import TrainConfig, build_train_step, init_opt_state

# long_500k requires sub-quadratic sequence mixing (see DESIGN.md section 5)
LONG_OK = {"recurrentgemma-9b", "xlstm-350m"}
# the paper's own model: training cells only (no decode path)
TRAIN_ONLY = {"mlp-pinn"}

# gradient-accumulation per arch for train_4k: keeps full-remat activation
# HBM (per-layer carries x layers) within a v5e chip. microbatch B must stay
# >= the data-axis extent (16 single-pod).
# tuned to the memory-constrained minimum: FSDP re-gathers weights once per
# microbatch (x remat recompute), so collective traffic scales linearly with
# accumulation — see EXPERIMENTS.md section Perf, final iteration.
GRAD_ACCUM = {
    "mistral-large-123b": 16,
    "llama3.2-vision-90b": 16,
    "arctic-480b": 16,
    "yi-6b": 4,
    "recurrentgemma-9b": 4,
    "llama3.2-3b": 4,
    "deepseek-moe-16b": 4,
    "xlstm-350m": 2,
    "qwen2-1.5b": 2,
}
# bf16 Adam moments where fp32 m,v would not fit a single pod
MOMENT_DTYPE = {"arctic-480b": "bfloat16", "mistral-large-123b": "bfloat16",
                "llama3.2-vision-90b": "bfloat16"}
# bf16 gradient-accumulation buffers for the largest models
ACCUM_DTYPE = {"arctic-480b": "bfloat16"}
# sequence-parallel residual boundaries (activation carries sharded over the
# TP axis; costs an AG/RS pair per layer — see EXPERIMENTS.md section Perf)
SEQ_SHARD = {"mistral-large-123b", "llama3.2-vision-90b", "arctic-480b"}

HW = {  # TPU v5e
    "peak_flops": 197e12,  # bf16 FLOP/s per chip
    "hbm_bw": 819e9,  # bytes/s per chip
    "ici_bw": 50e9,  # bytes/s per link (approx, per the assignment)
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def cells(include_multi=True):
    for arch in ARCHS:
        shapes = ["train_4k"] if arch in TRAIN_ONLY else list(SHAPES)
        for shape in shapes:
            if shape == "long_500k" and arch not in LONG_OK and arch not in TRAIN_ONLY:
                yield arch, shape, None  # recorded as a documented skip
                continue
            yield arch, shape, False
            if include_multi:
                yield arch, shape, True


def parse_collective_bytes(hlo_text: str):
    """Sum result-shape bytes of every collective op in the partitioned HLO."""
    per_kind = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = re.search(r"=\s+(\(.*?\)|\S+)\s+(" + "|".join(_COLLECTIVES) + r")[\.\s(]",
                      line)
        if not m:
            continue
        shapes_str, kind = m.group(1), m.group(2)
        total = 0
        for dt, dims in _SHAPE_RE.findall(shapes_str):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            total += n * _DTYPE_BYTES[dt]
        per_kind[kind] += total
        counts[kind] += 1
    return per_kind, counts


def model_flops(cfg, shape_cfg, params_shapes):
    flat, _ = jax.tree_util.tree_flatten_with_path(params_shapes)
    n_active = 0
    for kp, leaf in flat:
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
        n = math.prod(leaf.shape) if leaf.shape else 1
        if "embed" in path and "kernel" not in path:
            continue  # lookup table: no matmul flops (tied lm_head counted below)
        if "experts/" in path:
            n = n * cfg.experts_per_token / max(cfg.num_experts, 1)
        n_active += n
    if cfg.tied_embeddings or cfg.family in ("audio",):
        # unembedding matmul reuses the embedding table
        n_active += cfg.vocab_size * cfg.d_model
    tokens = shape_cfg.global_batch * shape_cfg.seq_len
    if shape_cfg.kind == "train":
        return 6.0 * n_active * tokens, n_active
    if shape_cfg.kind == "prefill":
        return 2.0 * n_active * tokens, n_active
    return 2.0 * n_active * shape_cfg.global_batch, n_active  # decode: 1 tok/seq


def total_param_count(params_shapes):
    return sum(math.prod(l.shape) if l.shape else 1
               for l in jax.tree_util.tree_leaves(params_shapes))


def _loss_fn(model, cfg):
    if cfg.family == "mlp":
        return lambda p, b: model.loss(p, b, cfg, method="collapsed")
    return lambda p, b: model.loss(p, b, cfg)


def batch_shardings(specs, mesh, batch_shardable=True):
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    def one(leaf):
        if not leaf.shape or not batch_shardable:
            return NamedSharding(mesh, P())
        n = 1
        for a in data_axes:
            n *= mesh.shape[a]
        if leaf.shape[0] % n == 0 and leaf.shape[0] >= n:
            return NamedSharding(mesh, P(data_axes))
        return NamedSharding(mesh, P())

    return jax.tree.map(one, specs)


def lower_cell(arch: str, shape_name: str, multi_pod: bool, compile_=True):
    cfg = get_config(arch)
    if cfg.family != "mlp":
        cfg = cfg.replace(param_dtype="bfloat16")  # deployable numerics
    shape_cfg = SHAPES[shape_name]
    if arch == "whisper-base":
        cfg = cfg.replace(max_target_positions=max(shape_cfg.seq_len + 1, 4096))
    model = get_model(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    key = jax.random.PRNGKey(0)

    params_shapes = jax.eval_shape(lambda: model.init(key, cfg))
    p_shard = shd.param_shardings(mesh, params_shapes)
    n_params = total_param_count(params_shapes)
    mflops, n_active = model_flops(cfg, shape_cfg, params_shapes)

    specs = model.input_specs(cfg, shape_cfg)
    batch_ok = shape_cfg.global_batch > 1
    b_shard = batch_shardings(specs, mesh, batch_ok)

    rules = None
    if arch in SEQ_SHARD and shape_cfg.kind == "train":
        rules = {"residual_seq": "model"}
    # cap accumulation so each microbatch still covers the batch-sharding
    # extent (a microbatch smaller than pod*data replicates activations)
    data_extent = math.prod(
        mesh.shape[a] for a in ("pod", "data") if a in mesh.axis_names
    )
    accum = min(GRAD_ACCUM.get(arch, 1),
                max(shape_cfg.global_batch // data_extent, 1))
    t0 = time.time()
    with shd.activate(mesh, rules):
        if shape_cfg.kind in ("train",):
            tcfg = TrainConfig(
                grad_accum=accum,
                moment_dtype=MOMENT_DTYPE.get(arch, "float32"),
                accum_dtype=ACCUM_DTYPE.get(arch, "float32"),
            )
            loss_fn = _loss_fn(model, cfg)
            step_fn = build_train_step(loss_fn, tcfg, grad_shardings=p_shard)
            opt_shapes = jax.eval_shape(lambda: init_opt_state(params_shapes, tcfg))
            o_shard = shd.param_shardings(mesh, opt_shapes)
            fn = jax.jit(
                step_fn,
                in_shardings=(p_shard, o_shard, b_shard, NamedSharding(mesh, P())),
                donate_argnums=(0, 1),
            )
            abstract_args = (params_shapes, opt_shapes, specs,
                             jax.ShapeDtypeStruct((), jnp.int32))
            traced = costmodel.traced_cost(step_fn, *abstract_args)
            lowered = fn.lower(*abstract_args)
        elif shape_cfg.kind == "prefill":
            def prefill_fn(params, batch):
                return model.forward(params, batch, cfg)[0]

            fn = jax.jit(prefill_fn, in_shardings=(p_shard, b_shard))
            traced = costmodel.traced_cost(prefill_fn, params_shapes, specs)
            lowered = fn.lower(params_shapes, specs)
        else:  # decode
            state_shapes = jax.eval_shape(
                lambda: model.init_decode_state(
                    cfg, shape_cfg.global_batch, shape_cfg.seq_len,
                    cfg.compute_dtype)
            )
            s_shard = shd.state_shardings(mesh, state_shapes, batch_ok)

            def serve_fn(params, state, tokens):
                logits, state = model.decode_step(params, state, tokens, cfg)
                return jnp.argmax(logits, axis=-1).astype(jnp.int32), state

            fn = jax.jit(
                serve_fn,
                in_shardings=(p_shard, s_shard, b_shard["tokens"]),
                donate_argnums=(1,),
            )
            traced = costmodel.traced_cost(serve_fn, params_shapes, state_shapes,
                                           specs["tokens"])
            lowered = fn.lower(params_shapes, state_shapes, specs["tokens"])
    t_lower = time.time() - t0

    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "pod2x16x16" if multi_pod else "16x16",
        "n_devices": math.prod(mesh.devices.shape),
        "kind": shape_cfg.kind,
        "n_params": n_params,
        "n_active_params": n_active,
        "model_flops": mflops,
        "lower_s": round(t_lower, 2),
        # scan-exact jaxpr cost model (GLOBAL); per-device = / n_devices
        "traced_flops": traced["flops"],
        "traced_bytes": traced["bytes"],
        "traced_transcendentals": traced["transcendentals"],
    }
    if not compile_:
        return result

    t0 = time.time()
    compiled = lowered.compile()
    result["compile_s"] = round(time.time() - t0, 2)

    mem = compiled.memory_analysis()
    for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        v = getattr(mem, attr, None)
        if v is not None:
            result[attr] = int(v)
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    if ca:
        result["hlo_flops"] = float(ca.get("flops", 0.0))
        result["hlo_bytes"] = float(ca.get("bytes accessed", 0.0))
        result["hlo_transcendentals"] = float(ca.get("transcendentals", 0.0))

    hlo_text = compiled.as_text()
    per_kind_raw, counts = parse_collective_bytes(hlo_text)
    per_kind, _ = costmodel.collective_bytes_scaled(hlo_text)
    result["collective_bytes"] = per_kind
    result["collective_bytes_unscaled"] = per_kind_raw
    result["collective_counts"] = counts
    result["collective_bytes_total"] = int(sum(per_kind.values()))
    return result


def roofline_terms(result):
    """The three terms in seconds per chip.

    flops/bytes come from the scan-exact jaxpr cost model (GLOBAL -> divide
    by chip count); collective bytes come from the partitioned HLO (already
    per-participant) with while-trip-count scaling.
    """
    n = result.get("n_devices", 1)
    flops = result.get("traced_flops", 0.0) / n
    byts = result.get("traced_bytes", 0.0) / n
    coll = result.get("collective_bytes_total", 0)
    terms = {
        "t_compute": flops / HW["peak_flops"],
        "t_memory": byts / HW["hbm_bw"],
        "t_collective": coll / HW["ici_bw"],
    }
    terms["bottleneck"] = max(terms, key=terms.get)
    mf = result.get("model_flops", 0.0)
    tf = result.get("traced_flops", 0.0)
    terms["useful_flops_frac"] = (mf / tf) if tf else 0.0
    return terms


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--no-compile", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    todo = []
    if args.all:
        for arch, shape, multi in cells(include_multi=not args.single_pod_only):
            todo.append((arch, shape, multi))
    else:
        todo.append((args.arch, args.shape, args.multi_pod))

    failures = 0
    for arch, shape, multi in todo:
        if multi is None:
            out = {
                "arch": arch, "shape": shape, "mesh": "skip",
                "skipped": "full-attention arch at 524k context (see DESIGN.md)",
            }
            tag = f"{arch}__{shape}__skip"
        else:
            tag = f"{arch}__{shape}__{'pod2x16x16' if multi else '16x16'}"
            path = os.path.join(args.out, tag + ".json")
            if os.path.exists(path):
                print(f"[cached] {tag}")
                continue
            print(f"[lower+compile] {tag} ...", flush=True)
            try:
                out = lower_cell(arch, shape, multi, compile_=not args.no_compile)
                out.update(roofline_terms(out))
                print(f"   ok: lower {out.get('lower_s')}s compile "
                      f"{out.get('compile_s')}s flops/dev {out.get('hlo_flops', 0):.3e} "
                      f"coll {out.get('collective_bytes_total', 0):.3e}B",
                      flush=True)
            except Exception as e:
                failures += 1
                out = {"arch": arch, "shape": shape,
                       "mesh": "pod2x16x16" if multi else "16x16",
                       "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-3000:]}
                print(f"   FAILED: {type(e).__name__}: {str(e)[:300]}", flush=True)
        with open(os.path.join(args.out, tag + ".json"), "w") as f:
            json.dump(out, f, indent=1)
    print(f"done; {failures} failures")
    return failures


if __name__ == "__main__":
    raise SystemExit(main())
