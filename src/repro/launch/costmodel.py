"""Jaxpr-level cost model: scan-exact FLOP/byte counting.

XLA's ``compiled.cost_analysis()`` counts while-loop (scan) bodies ONCE, which
under-reports scan-over-layers models by orders of magnitude (verified in
EXPERIMENTS.md section Dry-run). This walker counts the *traced* jaxpr with
correct scan multipliers. Conventions:

* flops: dot_general = 2 * |out| * K_contract; cheap elementwise = |out|;
  transcendentals = 10 * |out|; reductions/cumulatives = |in|.
* bytes (perfect-fusion HBM-traffic floor): traffic is counted ONLY at
  fusion boundaries — matmul operands/results and data-movement ops
  (gather/scatter/sort/concat); elementwise, transcendental and reduction ops
  are assumed fused into their producers (on TPU the softmax chain of a
  flash-attention chunk lives entirely in VMEM). Layout ops are free.
  Weights used inside a scan body count once per iteration (HBM re-read).
  This makes t_memory a lower bound and t_compute exact per jaxpr semantics.
* scan multiplies its body by ``length``; cond takes the max branch; grad-of-
  remat recompute appears explicitly in the jaxpr, so remat costs are exact.

Counts are GLOBAL (pre-partitioning); divide by device count for per-chip.
"""

from __future__ import annotations

import math
from typing import Any, Dict

import jax
import numpy as np

TRANSCENDENTAL = {
    "exp", "log", "log1p", "expm1", "tanh", "logistic", "sin", "cos", "tan",
    "erf", "erfc", "erf_inv", "rsqrt", "sqrt", "cbrt", "pow", "exp2",
    "atan2", "digamma", "lgamma",
}
CHEAP = {
    "add", "sub", "mul", "neg", "max", "min", "abs", "sign", "floor", "ceil",
    "round", "is_finite", "and", "or", "not", "xor", "eq", "ne", "lt", "le",
    "gt", "ge", "select_n", "clamp", "convert_element_type", "copy",
    "integer_pow", "square", "shift_left", "shift_right_logical",
    "shift_right_arithmetic", "rem", "nextafter", "real", "imag", "stop_gradient",
}
DIV = {"div"}
FREE = {
    "reshape", "broadcast_in_dim", "transpose", "squeeze", "expand_dims",
    "slice", "rev", "iota", "create_token", "constant", "sharding_constraint",
    "copy_p", "bitcast_convert_type", "split",
}
DATA = {
    "gather", "dynamic_slice", "dynamic_update_slice", "scatter",
    "scatter-add", "scatter_add", "concatenate", "pad", "top_k", "cumsum",
    "cummax", "cummin", "cumprod", "cumlogsumexp", "argmax", "argmin",
}
REDUCE = {"reduce_sum", "reduce_max", "reduce_min", "reduce_prod",
          "reduce_and", "reduce_or"}


def _size(aval) -> int:
    return math.prod(aval.shape) if aval.shape else 1


def _bytes(aval) -> int:
    return _size(aval) * np.dtype(aval.dtype).itemsize


class Cost:
    __slots__ = ("flops", "bytes", "transcendentals")

    def __init__(self, flops=0.0, byts=0.0, transcendentals=0.0):
        self.flops, self.bytes, self.transcendentals = flops, byts, transcendentals

    def __iadd__(self, o):
        self.flops += o.flops
        self.bytes += o.bytes
        self.transcendentals += o.transcendentals
        return self

    def scaled(self, k):
        return Cost(self.flops * k, self.bytes * k, self.transcendentals * k)

    def as_dict(self):
        return {"flops": self.flops, "bytes": self.bytes,
                "transcendentals": self.transcendentals}


def _eqn_cost(eqn) -> Cost:
    name = eqn.primitive.name
    outs = [v.aval for v in eqn.outvars]
    ins = [v.aval for v in eqn.invars]
    out_b = sum(_bytes(a) for a in outs)
    out_n = sum(_size(a) for a in outs)

    if name == "dot_general":
        (lc, rc), _ = eqn.params["dimension_numbers"]
        k = math.prod(ins[0].shape[d] for d in lc) if lc else 1
        flops = 2.0 * _size(outs[0]) * k
        return Cost(flops, sum(_bytes(a) for a in ins) + out_b)
    if name in TRANSCENDENTAL:
        return Cost(10.0 * out_n, 0.0, out_n)
    if name in DIV:
        return Cost(4.0 * out_n, 0.0)
    if name in CHEAP:
        return Cost(1.0 * out_n, 0.0)
    if name in FREE:
        return Cost(0.0, 0.0)
    if name in REDUCE:
        in_n = sum(_size(a) for a in ins)
        return Cost(float(in_n), out_b)  # input assumed fused w/ producer
    if name in DATA or name == "sort":
        return Cost(float(out_n), sum(_bytes(a) for a in ins) + out_b)
    # conservative default: elementwise-ish, fused
    return Cost(float(out_n), 0.0)


def jaxpr_cost(jaxpr) -> Cost:
    total = Cost()
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name == "scan":
            body = jaxpr_cost(eqn.params["jaxpr"].jaxpr)
            total += body.scaled(eqn.params["length"])
        elif name == "cond":
            branches = [jaxpr_cost(b.jaxpr) for b in eqn.params["branches"]]
            total += max(branches, key=lambda c: c.flops)
        elif name == "while":
            total += jaxpr_cost(eqn.params["body_jaxpr"].jaxpr)  # 1 trip (unknown)
        elif name in ("jit", "pjit", "closed_call", "custom_jvp_call",
                      "custom_vjp_call", "custom_vjp_call_jaxpr"):
            inner = (eqn.params.get("jaxpr") or eqn.params.get("call_jaxpr")
                     or eqn.params.get("fun_jaxpr"))
            total += jaxpr_cost(inner.jaxpr)
        elif name in ("remat", "checkpoint", "remat2"):
            inner = eqn.params["jaxpr"]
            total += jaxpr_cost(inner.jaxpr if hasattr(inner, "jaxpr") else inner)
        else:
            total += _eqn_cost(eqn)
    return total


def traced_cost(fn, *abstract_args) -> Dict[str, float]:
    closed = jax.make_jaxpr(fn)(*abstract_args)
    return jaxpr_cost(closed.jaxpr).as_dict()


# ---------------------------------------------------------------------------
# while-loop trip-count scaling for collective bytes parsed from HLO text
# ---------------------------------------------------------------------------


def computation_multipliers(hlo_text: str) -> Dict[str, int]:
    """Map computation name -> execution multiplier (product of enclosing
    while trip counts), using the loop-bound constant in each while condition.
    Heuristic but effective on XLA:CPU/SPMD output."""
    import re

    comps: Dict[str, list] = {}
    current = None
    for line in hlo_text.splitlines():
        m = re.match(r"^\s*(?:ENTRY\s+)?(%?[\w\.\-]+)\s*\(.*\)\s*->.*\{\s*$",
                     line)
        if m:
            current = m.group(1).lstrip("%")
            comps[current] = []
            continue
        if current is not None:
            comps[current].append(line)

    # find while instructions: condition=%c, body=%b
    whiles = []  # (parent_comp, cond, body)
    wre = re.compile(r"while\(.*?\).*condition=(%?[\w\.\-]+).*body=(%?[\w\.\-]+)")
    for comp, lines in comps.items():
        for line in lines:
            m = wre.search(line)
            if m:
                whiles.append((comp, m.group(1).lstrip("%"), m.group(2).lstrip("%")))

    def trip_count(cond_name):
        best = None
        for line in comps.get(cond_name, []):
            for c in re.findall(r"constant\((\d+)\)", line):
                v = int(c)
                if best is None or v > best:
                    best = v
        return best if best and best > 0 else 1

    mult = {name: 1 for name in comps}

    # iterate to fix point (nested whiles)
    for _ in range(8):
        changed = False
        for parent, cond, body in whiles:
            m = mult.get(parent, 1) * trip_count(cond)
            for target in (body, cond):
                if mult.get(target, 1) != m:
                    mult[target] = m
                    changed = True
        # propagate to computations *called* from scaled computations
        callre = re.compile(
            r"(?:calls=|to_apply=|condition=|body=|branch_computations=\{)"
            r"(%?[\w\.\-]+)"
        )
        for comp, lines in comps.items():
            for line in lines:
                for callee in callre.findall(line):
                    callee = callee.lstrip("%")
                    if callee in mult and mult[callee] < mult[comp]:
                        mult[callee] = mult[comp]
                        changed = True
        if not changed:
            break
    return mult


def collective_bytes_scaled(hlo_text: str):
    """Collective bytes with while-trip-count scaling; returns per-kind dict."""
    import re

    kinds = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")
    dtype_bytes = {
        "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
        "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    }
    shape_re = re.compile(r"(\w+)\[([\d,]*)\]")
    mult = computation_multipliers(hlo_text)

    per_kind = {k: 0 for k in kinds}
    counts = {k: 0 for k in kinds}
    current = None
    for line in hlo_text.splitlines():
        m = re.match(r"^\s*(?:ENTRY\s+)?(%?[\w\.\-]+)\s*\(.*\)\s*->.*\{\s*$",
                     line)
        if m:
            current = m.group(1).lstrip("%")
            continue
        m = re.search(r"=\s+(\(.*?\)|\S+)\s+(" + "|".join(kinds) + r")[\.\s(]", line)
        if not m:
            continue
        shapes_str, kind = m.group(1), m.group(2)
        total = 0
        for dt, dims in shape_re.findall(shapes_str):
            if dt not in dtype_bytes:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            total += n * dtype_bytes[dt]
        k = mult.get(current, 1)
        per_kind[kind] += total * k
        counts[kind] += 1
    return per_kind, counts
