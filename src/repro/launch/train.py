"""Production training launcher.

On a real TPU fleet each host runs:

    python -m repro.launch.train --arch <id> --coordinator <addr> \
        --num-processes <N> --process-id <i> [--multi-pod]

which initializes jax.distributed, builds the production mesh over the global
device set, shards params/optimizer with the FSDP+TP rules, and runs the
fault-tolerant Trainer (checkpoint/restart + straggler monitor + preemption
save). On this CPU container it runs the same code path single-process with
whatever devices exist (use --smoke for the reduced config).
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, get_config, get_smoke_config
from repro.data import collocation_batch, token_batch
from repro.distributed import sharding as shd
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import get_model
from repro.train.trainer import Trainer, TrainConfig, init_opt_state


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b", choices=ARCHS)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--production-mesh", action="store_true",
                    help="use the (16,16)/(2,16,16) v5e mesh (needs 256/512 chips)")
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--compressed-collectives", action="store_true",
                    help="explicit-DP shard_map step: int8 error-feedback "
                         "compressed gradient psum across ('pod','data')")
    ap.add_argument("--pods", type=int, default=None,
                    help="split host devices into a ('pod','data') mesh")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true",
                    help="require a restore from --ckpt-dir (exit 3 when no "
                         "complete checkpoint exists). The relaunch half of "
                         "the preemption path: the mesh may be SMALLER or "
                         "LARGER than the one that saved — per-device "
                         "error-feedback residuals re-shard automatically "
                         "(sum-fold/zero-pad, provenance logged) and stale "
                         "mesh-keyed offload plans are evicted. Without "
                         "--resume a restore is still attempted "
                         "opportunistically when --ckpt-dir is set.")
    ap.add_argument("--coordinator", default=None)
    ap.add_argument("--num-processes", type=int, default=1)
    ap.add_argument("--process-id", type=int, default=0)
    args = ap.parse_args()

    if args.coordinator:
        jax.distributed.initialize(args.coordinator, args.num_processes,
                                   args.process_id)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = get_model(cfg)
    mesh = (make_production_mesh(multi_pod=args.multi_pod)
            if args.production_mesh else make_host_mesh(pods=args.pods))

    with shd.activate(mesh):
        params = model.init(jax.random.PRNGKey(0), cfg)
        p_shard = shd.param_shardings(mesh, params)
        params = jax.device_put(params, p_shard)

        def batch_fn(step):
            if cfg.family == "mlp":
                return collocation_batch(0, step, args.batch, cfg.mlp_sizes[0])
            b = {"tokens": token_batch(0, step, args.batch, args.seq,
                                       cfg.vocab_size)}
            data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
            return jax.device_put(b, {"tokens": NamedSharding(mesh, P(data_axes))})

        data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        tcfg = TrainConfig(peak_lr=1e-3, warmup_steps=20, total_steps=args.steps,
                           grad_accum=args.grad_accum,
                           compress_grads=(args.compress_grads
                                           or args.compressed_collectives),
                           reduce_axis=(data_axes
                                        if args.compressed_collectives else None),
                           ckpt_dir=args.ckpt_dir)
        step_transform = None
        if args.compressed_collectives:
            # explicit DP: the step runs under shard_map, gradients cross the
            # pod links as int8 (compressed_psum_ef) instead of fp32 GSPMD
            # all-reduces. Params stay replicated; batch shards its leading
            # dim; EF residuals shard per-device. Batch specs come from a
            # sample batch: leaves whose leading dim doesn't divide the data
            # extent (e.g. small boundary-point sets) stay replicated.
            from repro.distributed.mesh_offload import dp_step_transform
            extent = 1
            for a in data_axes:
                extent *= int(mesh.shape[a])
            batch_spec = jax.tree.map(
                lambda a: (P(data_axes) if a.ndim and a.shape[0] % extent == 0
                           else P()),
                batch_fn(0))
            step_transform = dp_step_transform(mesh, compressed=True,
                                               data_axes=data_axes,
                                               batch_spec=batch_spec)
        trainer = Trainer(lambda p, b: model.loss(p, b, cfg), params, tcfg,
                          mesh=mesh,
                          param_shardings=(None if step_transform else p_shard),
                          batch_fn=batch_fn, step_transform=step_transform)
        if args.ckpt_dir:
            restored = trainer.maybe_restore()
            if restored:
                print(f"resumed from step {trainer.step}")
                for note in trainer.provenance:
                    print(f"provenance: {note}")
                # the relaunched mesh may be a different shape than the one
                # that planned the cached offloads — evict every plan keyed
                # to another mesh signature so nothing replays stale local
                # shard shapes (current-mesh and mesh-free plans stay warm)
                from repro.core.offload import evict_mesh_plans
                n_evicted = evict_mesh_plans()
                if n_evicted:
                    print(f"evicted {n_evicted} stale mesh-keyed offload "
                          f"plan(s) after mesh change")
            elif args.resume:
                raise SystemExit(
                    f"--resume: no complete checkpoint under "
                    f"{args.ckpt_dir!r} (nothing to resume from)")
        elif args.resume:
            raise SystemExit("--resume requires --ckpt-dir")
        trainer.run(args.steps, log_every=max(args.steps // 10, 1))
        if args.ckpt_dir:
            # leave a resumable final state even when the step count never
            # hit a ckpt_every boundary (no-op if this step already landed)
            trainer.save(synchronous=True)


if __name__ == "__main__":
    main()
