"""Production mesh definitions (TPU v5e pods: 16x16 = 256 chips per pod).

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state.
"""

from __future__ import annotations

import jax

from repro.distributed.sharding import compat_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_mesh(shape, axes)


def make_host_mesh(pods: int = None):
    """Whatever devices exist (tests/benches): a 1-D data mesh, or with
    ``pods`` a ('pod', 'data') mesh — pods x (n/pods) — for exercising the
    cross-pod compressed-collective path on host devices."""
    n = len(jax.devices())
    if pods and pods > 1:
        if n % pods:
            raise ValueError(f"{n} devices don't divide into {pods} pods")
        return compat_mesh((pods, n // pods), ("pod", "data"))
    return compat_mesh((n,), ("data",))
