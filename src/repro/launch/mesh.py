"""Production mesh definitions (TPU v5e pods: 16x16 = 256 chips per pod).

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_host_mesh():
    """Whatever devices exist (tests/benches): a 1-D data mesh."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
