"""Deterministic fault injection for the serving/offload stack.

Chaos testing needs faults that are *reproducible*: every injector here is a
context manager with an explicit trigger (call count, request id) and no
randomness, so a failing chaos run replays exactly. Each yields a
:class:`FaultStats` counter object and restores the patched seam on exit.

* :func:`kernel_raise` — make the offload engine's kernel entry points
  raise a classified kernel failure (``InjectedKernelFault`` with a
  RESOURCE_EXHAUSTED-style message) for their first ``n`` calls. With
  ``where="kernel"`` (default) the raise happens inside ``try_fuse`` — the
  plan-level path, where the circuit breaker degrades the segment in place.
  With ``where="step"`` it happens at the operator engine's compiled-step
  seam — the runtime path, exercising ``record_kernel_failure`` + backoff +
  re-trace.
* :func:`nan_inject` — corrupt the payload of selected operator requests at
  submit time (first point -> NaN) so the in-jit ``isfinite`` quarantine is
  exercised end-to-end.
* :func:`slow_step` — add a fixed sleep per engine step (deadline-eviction
  pressure).
* :func:`queue_flood` — driver helper: submit a burst of requests
  back-to-back (admission-control pressure).
"""

from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Callable, Iterable, List, Optional

import numpy as np

from repro.kernels.failures import InjectedKernelFault

_KERNEL_ATTRS = {
    "mlp": "collapsed_jet_layer_op",
    "attention": "collapsed_jet_attention_op",
    "qkv": "collapsed_jet_qkv_attention_op",
}

_DEFAULT_MESSAGE = ("RESOURCE_EXHAUSTED: injected fault — VMEM allocation "
                    "failed for kernel launch")


@dataclasses.dataclass
class FaultStats:
    """Counters exposed by every injector: total seam ``calls`` seen and
    ``injected`` faults actually fired."""

    calls: int = 0
    injected: int = 0


@contextlib.contextmanager
def kernel_raise(n: int = 1, kinds: Iterable[str] = ("mlp",),
                 where: str = "kernel", message: str = _DEFAULT_MESSAGE):
    """Raise a classified kernel failure on the first ``n`` calls.

    ``kinds``: which kernel entry points to fault ("mlp", "attention",
    "qkv") — only meaningful for ``where="kernel"``. ``where="step"``
    patches :meth:`OperatorEngine._execute` instead, so the failure
    surfaces *after* tracing like a real runtime launch failure.
    """
    stats = FaultStats()
    if where == "kernel":
        from repro.core import offload

        originals = {}

        def wrap(orig):
            left = [n]

            def inner(*a, **k):
                stats.calls += 1
                if left[0] > 0:
                    left[0] -= 1
                    stats.injected += 1
                    raise InjectedKernelFault(message)
                return orig(*a, **k)

            return inner

        try:
            for kd in kinds:
                attr = _KERNEL_ATTRS[kd]
                originals[attr] = getattr(offload, attr)
                setattr(offload, attr, wrap(originals[attr]))
            yield stats
        finally:
            for attr, fn in originals.items():
                setattr(offload, attr, fn)
    elif where == "step":
        from repro.serve import operator_engine as oe

        orig = oe.OperatorEngine._execute
        left = [n]

        def _execute(self, fn, x):
            stats.calls += 1
            if left[0] > 0:
                left[0] -= 1
                stats.injected += 1
                raise InjectedKernelFault(message)
            return orig(self, fn, x)

        oe.OperatorEngine._execute = _execute
        try:
            yield stats
        finally:
            oe.OperatorEngine._execute = orig
    else:
        raise ValueError(f"where must be 'kernel' or 'step', got {where!r}")


@contextlib.contextmanager
def nan_inject(rids: Optional[Iterable[int]] = None):
    """Corrupt matching operator requests at submit (``points[0, 0] = NaN``).

    ``rids=None`` corrupts every submitted request. The corruption happens
    *before* validation/enqueue, so the NaN flows through the jit'd step and
    must be caught by the per-slot quarantine, not by host-side screening.
    """
    from repro.serve import operator_engine as oe

    targets = None if rids is None else set(rids)
    orig = oe.OperatorEngine.submit
    stats = FaultStats()

    def submit(self, req):
        stats.calls += 1
        if targets is None or req.rid in targets:
            pts = np.array(req.points, dtype=np.float32, copy=True)
            if pts.ndim == 2 and pts.size:
                pts[0, 0] = np.nan
                req.points = pts
                stats.injected += 1
        return orig(self, req)

    oe.OperatorEngine.submit = submit
    try:
        yield stats
    finally:
        oe.OperatorEngine.submit = orig


@contextlib.contextmanager
def slow_step(seconds: float = 0.05, every: int = 1):
    """Sleep ``seconds`` before every ``every``-th compiled-step execution
    (deadline pressure without touching numerics)."""
    from repro.serve import operator_engine as oe

    orig = oe.OperatorEngine._execute
    stats = FaultStats()

    def _execute(self, fn, x):
        stats.calls += 1
        if stats.calls % every == 0:
            stats.injected += 1
            time.sleep(seconds)
        return orig(self, fn, x)

    oe.OperatorEngine._execute = _execute
    try:
        yield stats
    finally:
        oe.OperatorEngine._execute = orig


def queue_flood(engine, n: int,
                make_request: Callable[[int], "object"]) -> List["object"]:
    """Submit ``n`` requests back-to-back (admission-control pressure);
    returns them — statuses show what was shed vs queued."""
    reqs = [make_request(i) for i in range(n)]
    for r in reqs:
        engine.submit(r)
    return reqs
