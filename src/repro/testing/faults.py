"""Deterministic fault injection for the serving/offload/training stack.

Chaos testing needs faults that are *reproducible*: every injector here is a
context manager with an explicit trigger (call count, request id, step
number, shard index) and no randomness, so a failing chaos run replays
exactly. Each yields a :class:`FaultStats` counter object and restores the
patched seam on exit — installation is unwound in reverse install order even
when patching itself raises partway through (see :func:`_patch_all`), so a
bad ``kinds`` entry can never leave an earlier seam patched.

Serving-side injectors (PR 7):

* :func:`kernel_raise` — make the offload engine's kernel entry points
  raise a classified kernel failure (``InjectedKernelFault`` with a
  RESOURCE_EXHAUSTED-style message) for their first ``n`` calls. With
  ``where="kernel"`` (default) the raise happens inside ``try_fuse`` — the
  plan-level path, where the circuit breaker degrades the segment in place.
  With ``where="step"`` it happens at the operator engine's compiled-step
  seam — the runtime path, exercising ``record_kernel_failure`` + backoff +
  re-trace.
* :func:`corrupt_kernel_output` — *silent* data corruption: the kernel
  entry points return perturbed (finite, wrong) numbers instead of
  raising, the fault class only the sentinel audits
  (:mod:`repro.core.sentinel`) can catch. Trace-scoped.
* :func:`nan_inject` — corrupt the payload of selected operator requests at
  submit time (first point -> NaN) so the in-jit ``isfinite`` quarantine is
  exercised end-to-end.
* :func:`slow_step` — add a fixed sleep per engine step (deadline-eviction
  pressure).
* :func:`queue_flood` — driver helper: submit a burst of requests
  back-to-back (admission-control pressure).

Training-side injectors (shard-targeted, for the distributed chaos drill):

* :func:`shard_nan_grads` — NaN one shard's slice of the global batch at
  chosen steps, so exactly that shard's local loss/grads go non-finite and
  the cross-shard consensus must quarantine it (healthy shards commit).
* :func:`slow_train_step` — straggler: sleep at the trainer's step seam.
* :func:`train_step_raise` — raise a classified distributed failure
  (collective-timeout message by default) at the step seam, BEFORE the jit
  call consumes the donated buffers, exercising retry + backoff.
* :func:`corrupt_collective` — trace-scoped: wrap the trainer module's
  compressed collective so the *reduced* gradient is poisoned post-psum
  (every shard sees the same garbage — the mesh-wide skip leg of the
  consensus). Install before building/``retrace()``-ing the step; a jit
  trace cached before install is NOT affected.
* :func:`kill_at_step` — preemption at step N: ``mode="sigterm"`` flips the
  trainer's graceful-preemption flag (finish the step, sync-save, stop);
  ``mode="hard"`` raises a classified ``preempted`` failure (non-retryable
  -> save-and-interrupt with the relaunch runbook).
"""

from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Callable, Dict, Iterable, List, Optional

import numpy as np

from repro.kernels.failures import InjectedKernelFault

_KERNEL_ATTRS = {
    "mlp": "collapsed_jet_layer_op",
    "attention": "collapsed_jet_attention_op",
    "qkv": "collapsed_jet_qkv_attention_op",
}

_DEFAULT_MESSAGE = ("RESOURCE_EXHAUSTED: injected fault — VMEM allocation "
                    "failed for kernel launch")

_COLLECTIVE_MESSAGE = ("DEADLINE_EXCEEDED: injected fault — collective "
                       "all-reduce timed out waiting for remote shard")

_PREEMPT_MESSAGE = ("UNAVAILABLE: injected fault — host preempted "
                    "(maintenance event), SIGTERM grace period started")

_MISSING = object()


@contextlib.contextmanager
def _patch_all(patches):
    """Install ``(obj, attr, new)`` patches in order; ALWAYS unwind in
    reverse install order — including when a later installation raises, so a
    partially-installed set never leaks past the context manager. ``obj``
    may be a module, class, or instance; an attr the object didn't own
    (e.g. an instance shadowing a class method) is removed again rather than
    copied down."""
    installed = []  # (obj, attr, old) in install order
    try:
        for obj, attr, new in patches:
            old = obj.__dict__.get(attr, _MISSING)
            setattr(obj, attr, new)
            installed.append((obj, attr, old))
        yield
    finally:
        for obj, attr, old in reversed(installed):
            if old is _MISSING:
                try:
                    delattr(obj, attr)
                except AttributeError:
                    pass
            else:
                setattr(obj, attr, old)


@dataclasses.dataclass
class FaultStats:
    """Counters exposed by every injector: total seam ``calls`` seen,
    ``injected`` faults actually fired, and (for the shard-targeted
    training injectors) ``per_shard`` injection counts keyed by shard
    index."""

    calls: int = 0
    injected: int = 0
    per_shard: Dict[int, int] = dataclasses.field(default_factory=dict)

    def record_shard(self, shard: int, n: int = 1):
        """Count ``n`` injections against ``shard`` (and in ``injected``)."""
        self.injected += n
        self.per_shard[shard] = self.per_shard.get(shard, 0) + n


# --------------------------------------------------------------------------
# serving-side injectors
# --------------------------------------------------------------------------


@contextlib.contextmanager
def kernel_raise(n: int = 1, kinds: Iterable[str] = ("mlp",),
                 where: str = "kernel", message: str = _DEFAULT_MESSAGE):
    """Raise a classified kernel failure on the first ``n`` calls.

    ``kinds``: which kernel entry points to fault ("mlp", "attention",
    "qkv") — only meaningful for ``where="kernel"``. ``where="step"``
    patches :meth:`OperatorEngine._execute` instead, so the failure
    surfaces *after* tracing like a real runtime launch failure.
    """
    stats = FaultStats()
    if where == "kernel":
        from repro.core import offload

        def wrap(orig):
            left = [n]

            def inner(*a, **k):
                stats.calls += 1
                if left[0] > 0:
                    left[0] -= 1
                    stats.injected += 1
                    raise InjectedKernelFault(message)
                return orig(*a, **k)

            return inner

        patches = [(offload, _KERNEL_ATTRS[kd],
                    wrap(getattr(offload, _KERNEL_ATTRS[kd])))
                   for kd in kinds]
        with _patch_all(patches):
            yield stats
    elif where == "step":
        from repro.serve import operator_engine as oe

        orig = oe.OperatorEngine._execute
        left = [n]

        def _execute(self, fn, x):
            stats.calls += 1
            if left[0] > 0:
                left[0] -= 1
                stats.injected += 1
                raise InjectedKernelFault(message)
            return orig(self, fn, x)

        with _patch_all([(oe.OperatorEngine, "_execute", _execute)]):
            yield stats
    else:
        raise ValueError(f"where must be 'kernel' or 'step', got {where!r}")


@contextlib.contextmanager
def corrupt_kernel_output(kinds: Iterable[str] = ("mlp",),
                          scale: float = 1e-2):
    """Silently corrupt fused kernel outputs — no exception, wrong numbers.

    The fault class nothing in the exception-classified chaos menu can
    catch: every floating output ``y`` of the patched kernel entry points
    becomes ``y * (1 + scale) + scale`` (finite, deterministic, well
    outside the sentinel tolerance budgets at the default ``scale=1e-2``).
    Only the sentinel audits (:mod:`repro.core.sentinel`) can detect it, by
    recomputing sampled windows through the CRULES oracle.

    Trace-scoped like :func:`corrupt_collective`: the kernel ops run at
    *trace* time, so the perturbation is baked into whatever jit caches
    trace inside the context, and exiting does not heal them — the serving
    engine re-traces per ``breaker_epoch``, which is exactly the recovery
    path under test. ``stats.injected`` counts corrupted trace sites, not
    executions.
    """
    import jax
    import jax.numpy as jnp

    from repro.core import offload

    stats = FaultStats()

    def wrap(orig):
        def perturb(leaf):
            if (hasattr(leaf, "dtype")
                    and jnp.issubdtype(leaf.dtype, jnp.floating)):
                return leaf * (1.0 + scale) + jnp.asarray(scale, leaf.dtype)
            return leaf

        def inner(*a, **k):
            stats.calls += 1
            stats.injected += 1
            out = orig(*a, **k)
            return jax.tree_util.tree_map(perturb, out)

        return inner

    patches = [(offload, _KERNEL_ATTRS[kd],
                wrap(getattr(offload, _KERNEL_ATTRS[kd])))
               for kd in kinds]
    with _patch_all(patches):
        yield stats


@contextlib.contextmanager
def nan_inject(rids: Optional[Iterable[int]] = None):
    """Corrupt matching operator requests at submit (``points[0, 0] = NaN``).

    ``rids=None`` corrupts every submitted request. The corruption happens
    *before* validation/enqueue, so the NaN flows through the jit'd step and
    must be caught by the per-slot quarantine, not by host-side screening.
    """
    from repro.serve import operator_engine as oe

    targets = None if rids is None else set(rids)
    orig = oe.OperatorEngine.submit
    stats = FaultStats()

    def submit(self, req):
        stats.calls += 1
        if targets is None or req.rid in targets:
            pts = np.array(req.points, dtype=np.float32, copy=True)
            if pts.ndim == 2 and pts.size:
                pts[0, 0] = np.nan
                req.points = pts
                stats.injected += 1
        return orig(self, req)

    with _patch_all([(oe.OperatorEngine, "submit", submit)]):
        yield stats


@contextlib.contextmanager
def slow_step(seconds: float = 0.05, every: int = 1):
    """Sleep ``seconds`` before every ``every``-th compiled-step execution
    (deadline pressure without touching numerics)."""
    from repro.serve import operator_engine as oe

    orig = oe.OperatorEngine._execute
    stats = FaultStats()

    def _execute(self, fn, x):
        stats.calls += 1
        if stats.calls % every == 0:
            stats.injected += 1
            time.sleep(seconds)
        return orig(self, fn, x)

    with _patch_all([(oe.OperatorEngine, "_execute", _execute)]):
        yield stats


def queue_flood(engine, n: int,
                make_request: Callable[[int], "object"]) -> List["object"]:
    """Submit ``n`` requests back-to-back (admission-control pressure);
    returns them — statuses show what was shed vs queued."""
    reqs = [make_request(i) for i in range(n)]
    for r in reqs:
        engine.submit(r)
    return reqs


# --------------------------------------------------------------------------
# training-side injectors (shard-targeted)
# --------------------------------------------------------------------------


@contextlib.contextmanager
def shard_nan_grads(trainer, shards: Iterable[int] = (0,),
                    at_steps: Iterable[int] = (2,),
                    n_shards: Optional[int] = None):
    """NaN the targeted shards' slice of the global batch at the given steps.

    Under explicit DP the global batch is split contiguously over the data
    axes, so poisoning rows ``[s*per, (s+1)*per)`` makes exactly shard ``s``'s
    local loss/gradients non-finite — the cross-shard consensus must
    quarantine that shard (``metrics["skipped_shards"]``) while every healthy
    shard commits. Host-side injection at the ``batch_fn`` seam: it works
    against an already-cached jit trace (no retrace needed) and replays
    deterministically. ``n_shards`` defaults to the trainer's data-axis
    device count."""
    total = n_shards if n_shards is not None else trainer._ef_devices
    orig = trainer.batch_fn
    stats = FaultStats()
    steps = set(int(s) for s in at_steps)
    targets = tuple(int(s) for s in shards)
    for s in targets:
        if not 0 <= s < total:
            raise ValueError(f"shard {s} out of range for {total} shards")

    def batch_fn(step):
        stats.calls += 1
        batch = orig(step)
        if int(step) not in steps:
            return batch

        def corrupt(x):
            x = np.array(x, copy=True)
            per = x.shape[0] // total
            for s in targets:
                x[s * per:(s + 1) * per] = np.nan
            return x

        import jax
        batch = jax.tree.map(corrupt, batch)
        for s in targets:
            stats.record_shard(s)
        return batch

    with _patch_all([(trainer, "batch_fn", batch_fn)]):
        yield stats


@contextlib.contextmanager
def slow_train_step(trainer, seconds: float = 0.05, every: int = 1,
                    shard: Optional[int] = None):
    """Straggler injection: sleep before every ``every``-th step launch at
    the trainer's :meth:`_execute_step` seam (watchdog/EWMA pressure without
    touching numerics). ``shard`` only labels the ``per_shard`` counter —
    in-process the whole mesh steps together, so a slow shard IS a slow
    step."""
    orig = trainer._execute_step
    stats = FaultStats()

    def _execute_step(params, opt_state, batch, step):
        stats.calls += 1
        if stats.calls % every == 0:
            if shard is not None:
                stats.record_shard(shard)
            else:
                stats.injected += 1
            time.sleep(seconds)
        return orig(params, opt_state, batch, step)

    with _patch_all([(trainer, "_execute_step", _execute_step)]):
        yield stats


@contextlib.contextmanager
def train_step_raise(trainer, n: int = 1, message: str = _COLLECTIVE_MESSAGE,
                     shard: Optional[int] = None):
    """Raise a classified distributed failure on the first ``n`` step
    launches. The raise happens at the :meth:`_execute_step` seam *before*
    the jit call, so the donated params/opt-state buffers are still alive
    and the trainer's bounded retry + backoff path is safe to exercise. The
    default message classifies as the retryable ``collective`` family; pass
    a ``halted``/``preempt`` message to hit the other families."""
    orig = trainer._execute_step
    stats = FaultStats()
    left = [n]

    def _execute_step(params, opt_state, batch, step):
        stats.calls += 1
        if left[0] > 0:
            left[0] -= 1
            if shard is not None:
                stats.record_shard(shard)
            else:
                stats.injected += 1
            raise InjectedKernelFault(message)
        return orig(params, opt_state, batch, step)

    with _patch_all([(trainer, "_execute_step", _execute_step)]):
        yield stats


@contextlib.contextmanager
def corrupt_collective(kind: str = "nan"):
    """Poison the trainer's compressed gradient collective POST-reduction
    (``kind``: "nan" or "inf") — every shard receives the same corrupted
    mean, so the consensus must skip the step mesh-wide
    (``skipped_nonfinite``) with zero per-shard quarantines.

    Trace-scoped: the wrapper is baked in at trace time, so install this
    BEFORE the trainer builds (or ``trainer.retrace()``) and retrace again
    after exit to heal — a step cached before install is untouched.
    ``stats.injected`` counts trace-time wrap sites, not steps run."""
    import jax.numpy as jnp

    from repro.train import trainer as trainer_mod

    if kind not in ("nan", "inf"):
        raise ValueError(f"kind must be 'nan' or 'inf', got {kind!r}")
    bad = float("nan") if kind == "nan" else float("inf")
    orig = trainer_mod.compressed_psum_ef
    stats = FaultStats()

    def corrupted(x, err, axis_name, ok=None):
        stats.calls += 1
        stats.injected += 1
        mean, new_err = orig(x, err, axis_name, ok=ok)
        return mean + jnp.asarray(bad, dtype=mean.dtype), new_err

    with _patch_all([(trainer_mod, "compressed_psum_ef", corrupted)]):
        yield stats


@contextlib.contextmanager
def kill_at_step(trainer, step: int, mode: str = "sigterm"):
    """Preempt the trainer when it reaches ``step``.

    ``mode="sigterm"`` flips the trainer's graceful-preemption flag exactly
    as the real SIGTERM handler does — the in-flight step completes, the
    loop sync-saves (draining the async writer first) and stops.
    ``mode="hard"`` raises a classified ``preempted`` failure at the step
    seam — non-retryable, so the trainer sync-saves and raises
    :class:`~repro.train.trainer.TrainingInterrupted` with the relaunch
    runbook. Both leave a checkpoint at the kill step for ``--resume``."""
    if mode not in ("sigterm", "hard"):
        raise ValueError(f"mode must be 'sigterm' or 'hard', got {mode!r}")
    orig = trainer._execute_step
    stats = FaultStats()
    fired = [False]

    def _execute_step(params, opt_state, batch, s):
        stats.calls += 1
        if not fired[0] and int(s) >= step:
            fired[0] = True
            stats.injected += 1
            if mode == "sigterm":
                trainer._on_sigterm()
            else:
                raise InjectedKernelFault(_PREEMPT_MESSAGE)
        return orig(params, opt_state, batch, s)

    with _patch_all([(trainer, "_execute_step", _execute_step)]):
        yield stats
