"""Test-support utilities shipped with the package (importable from tests,
benchmarks, and chaos drills alike).

:mod:`repro.testing.faults` — deterministic fault injection for the
serving/offload stack: kernel-raise, NaN-inject, slow-step, queue-flood.
"""

from . import faults  # noqa: F401
