"""Mesh-sharded collapsed-jet offload: run the fused kernel stack on a mesh.

Two parallelism axes compose with ``method='collapsed', backend='pallas'``:

* **Data parallel** (:func:`shard_operator`) — PDE operators are
  embarrassingly parallel over collocation points, so the collapsed
  (R, B, S, D) jet bundle shards over the ('pod', 'data') mesh axes on its
  *batch* dim (the leading jet axis R is never sharded — the ``"jet"``
  logical rule). Each device runs the full recursive offload plan on its
  local shard: one superblock kernel per layer per device, bit-identical to
  evaluating the unsharded operator on that shard's rows. Planning happens
  once per mesh shape (the plan-cache key carries the mesh signature; see
  ``core/offload.py``) and prewarms under the local shard batch.

* **Tensor parallel** (:func:`tp_qkv_attention`) — the QKV-attention
  superblock partitions over the ``'model'`` axis along the kernel's
  existing kv-head grid dimension: each device owns ``Hkv / tp`` kv groups
  and the matching slices of Wq/Wk/Wv/Wo (the rank-3 (D, H, dh) projection
  layouts shard on their head axis per ``sharding.param_logical_axes`` —
  the ``("fsdp", "heads", "head_dim")`` / ``("heads", "head_dim", "fsdp")``
  rules). Softmax is per-head, so head-sharding is exact; the only
  collective is the output-side psum that completes the Wo accumulation
  (the graph value of the output projection is a sum over heads).

Cross-pod gradient reductions for training on top of these ride
``collectives.compressed_psum`` — see ``train/trainer.py``.
"""

from __future__ import annotations

from typing import Callable, Sequence

import jax
from jax.sharding import PartitionSpec as P

try:  # moved in newer JAX
    from jax.experimental.shard_map import shard_map
except ImportError:  # pragma: no cover
    from jax.shard_map import shard_map


def _shard_map(fn, mesh, in_specs, out_specs):
    """``shard_map`` with replication checking off.

    ``pallas_call`` has no replication rule, so the rep checker rejects any
    shard-mapped body that reaches the fused kernels. The flag was renamed
    ``check_rep`` -> ``check_vma`` across JAX versions; try both.
    """
    try:
        return shard_map(fn, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_rep=False)
    except TypeError:  # pragma: no cover - newer JAX
        return shard_map(fn, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_vma=False)


def data_axes_of(mesh, data_axes: Sequence[str] = ("pod", "data")):
    """The data-parallel axes present on this mesh, in mesh order."""
    return tuple(a for a in data_axes if a in mesh.axis_names)


def shard_operator(op: Callable, mesh, *,
                   data_axes: Sequence[str] = ("pod", "data")) -> Callable:
    """Data-parallel wrapper for a ``core.operators`` differential operator.

    ``op(f, x, **kw)`` must be batch-leading in ``x`` (dim 0 = collocation
    points) and per-point in its output — true of ``laplacian`` /
    ``biharmonic`` / friends. Returns ``wrapped(f, x, **kw)`` that runs
    ``op`` under ``shard_map`` with ``x`` (and the output) sharded over the
    mesh's ('pod', 'data') axes: each device plans and executes the fused
    collapsed-jet kernels on its local rows only. ``f``'s closed-over
    parameters are replicated (broadcast once by the partitioner).

        mesh = compat_mesh((8,), ('data',))
        lap = shard_operator(partial(ops.laplacian, method='collapsed',
                                     backend='pallas'), mesh)
        u_xx = jax.jit(lambda x: lap(f, x))(x_global)   # (B,) sharded

    The global batch must divide by the data-axis extent (uneven shards are
    unsupported throughout, see ``sharding.divisible_spec``).
    """
    axes = data_axes_of(mesh, data_axes)
    spec = P(axes) if axes else P()

    def wrapped(f, x, **kw):
        local = _shard_map(lambda xs: op(f, xs, **kw), mesh,
                           in_specs=spec, out_specs=spec)
        return local(x)

    return wrapped


def dp_step_transform(mesh, *, compressed: bool = False,
                      data_axes: Sequence[str] = ("pod", "data"),
                      batch_spec=None) -> Callable:
    """Build a ``Trainer(step_transform=...)`` wrapper: run the train step
    under ``shard_map`` over the mesh's data axes (explicit data parallelism).

    The wrapped step signature is ``(params, opt_state, batch, step)``:
    params and the adam state stay replicated (``P()``), the batch shards its
    leading dim over the data axes (``batch_spec`` overrides the default
    ``P(axes)`` prefix for ragged batch trees), and — with ``compressed`` —
    the error-feedback buffers shard their leading per-device axis so each
    device keeps its own residual. Pair with
    ``TrainConfig(reduce_axis=<axes>, compress_grads=True)`` so the step
    completes the gradient average through
    ``collectives.compressed_psum_ef`` (int8 on the wire).
    """
    axes = data_axes_of(mesh, data_axes)
    bspec = P(axes) if batch_spec is None else batch_spec
    ospec = {"adam": P(), "ef": P(axes)} if compressed else P()

    def transform(step_fn):
        return _shard_map(step_fn, mesh,
                          in_specs=(P(), ospec, bspec, P()),
                          out_specs=(P(), ospec, P()))

    return transform


def tp_qkv_attention(h, wq, wk, wv, wo, *, axis_name: str = "model",
                     K: int = 2, **kw):
    """Tensor-parallel collapsed-jet QKV-attention superblock (call inside
    ``shard_map`` over ``axis_name``).

    ``h`` is the replicated collapsed-jet triple ``(h0, lower, top)`` of
    the pre-projection hidden states (see
    ``kernels.jet_attention.ops.collapsed_jet_qkv_attention_op``); the
    weights are this device's kv-group slices in their graph layouts —
    ``wq`` (D, Hq/tp, dh), ``wk`` (D, Hkv/tp, dh), ``wv`` (D, Hkv/tp, dv),
    ``wo`` (Hq/tp, dv, Do), i.e. the head ('model'-mapped) axis of the
    rank-3 projection layouts sharded per ``sharding.param_logical_axes``.
    ``Hkv`` must divide by the axis size (the kernel grids over kv groups,
    so a shard owns whole groups and the grid just shrinks).

    Each device runs ONE fused kernel over its local kv groups; softmax is
    per-head so the local result is exact, and the returned bundle is
    completed with an output-side psum over ``axis_name`` — the Wo
    accumulation ``sum_h head_out_h @ Wo[h]`` distributes over the head
    shards (every coefficient lane of the jet is a head-sum, so the psum
    applies to primal, lower and top alike). ``kw`` passes through to the
    superblock op (mask/scale/bias/rope/qkv_bias/...); note per-head
    operands (ALiBi bias tables, qkv biases) must be sliced consistently
    with the weights.
    """
    from repro.kernels.jet_attention.ops import collapsed_jet_qkv_attention_op

    o0, ol, ot = collapsed_jet_qkv_attention_op(h, wq, wk, wv, wo, K=K, **kw)

    def ps(c):
        return None if c is None else jax.lax.psum(c, axis_name)

    return ps(o0), [ps(c) for c in ol], ps(ot)
