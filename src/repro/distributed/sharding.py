"""Logical-axis sharding rules (t5x-style) for the (pod, data, model) mesh.

Models annotate activations with *logical* axis names via :func:`lshard`;
parameters get specs from :func:`param_spec` by path. The mapping from logical
names to physical mesh axes lives here, so switching the parallelism layout is
a one-table change (used by the perf hillclimb in EXPERIMENTS.md section Perf).

Conventions (single-pod mesh ('data','model') = (16,16); multi-pod adds 'pod'):

  batch            -> ('pod', 'data')   data parallel over pods x data axis
  heads/mlp/vocab/experts -> 'model'    tensor / expert parallel
  fsdp             -> ('pod', 'data')   parameter sharding axis (FSDP)
  seq              -> None by default; 'data' for sequence-parallel recurrent
                      archs on long_500k (they are batch=1)

No-ops when no mesh has been activated (single-device tests/benches).
"""

from __future__ import annotations

import re
import threading
from contextlib import contextmanager
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


DEFAULT_RULES = {
    # the leading direction axis R of a collapsed (R, B, S, D) jet bundle:
    # NEVER mesh-sharded. The fused collapsed-jet kernels grid over R on every
    # device (block_r), and the top/primal lanes have no R axis at all — a
    # shard boundary through R would split single kernel invocations. Data
    # parallelism shards the *batch* axis of the bundle instead (one
    # superblock kernel per layer per device over the local collocation
    # points); tensor parallelism shards kv-head grids via 'heads'.
    "jet": None,
    "batch": ("pod", "data"),
    "seq": None,
    # residual-stream sequence axis at scan-block boundaries: mapping this to
    # 'model' enables Megatron-style sequence parallelism — saved activation
    # carries shrink by the TP degree at the cost of an all-gather/reduce-
    # scatter pair per layer (used for the 100B+ train cells; see section Perf)
    "residual_seq": None,
    "kv_seq": None,
    "embed": None,
    "heads": "model",
    "kv_heads": None,
    "head_dim": None,
    "mlp": "model",
    "vocab": "model",
    "experts": "model",
    "expert_capacity": None,
    "expert_mlp": None,
    "fsdp": ("pod", "data"),
    "state": "model",
}


def compat_mesh(axis_shapes, axis_names) -> Mesh:
    """Construct a device mesh portably across JAX versions.

    ``jax.sharding.AxisType`` (and ``jax.make_mesh``'s ``axis_types`` kwarg)
    only exist on newer JAX; older releases behave as if every axis were
    Auto. Callers that want plain Auto axes should use this instead of
    touching ``AxisType`` directly."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(axis_shapes, axis_names,
                                 axis_types=(axis_type.Auto,) * len(axis_names))
        except TypeError:  # make_mesh predates axis_types
            pass
    return jax.make_mesh(axis_shapes, axis_names)


def _rules():
    return getattr(_state, "rules", None)


def _mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


@contextmanager
def activate(mesh: Mesh, rules: Optional[dict] = None):
    """Enable sharding annotations for code run inside this context.

    Binds ``mesh`` (and ``DEFAULT_RULES`` merged with ``rules``) to a
    thread-local slot that :func:`lshard` / :func:`param_spec` read; rules
    naming mesh axes absent from this mesh are dropped (e.g. 'pod' on a
    single-pod mesh), so one rule table serves every layout. The binding is
    thread-local and re-entrant — a concurrent trace in another thread keeps
    its own (or no) mesh.

    The active mesh is also what makes the *collapsed-jet offload engine*
    mesh-aware: ``core/offload.py`` folds the activated mesh's axis layout
    into its plan-cache key (one plan per mesh shape — see
    ``offload.plan_cache_info``) and prewarms kernel block configs under the
    *local shard* batch shape (global batch / data-axis extent) instead of
    the global one, so autotuned blocks match what each device actually
    runs. Code traced *inside* ``shard_map`` already sees local shapes and
    needs no activation for that; activate the mesh for the jit-on-mesh
    (GSPMD) path and for :func:`lshard` constraints.
    """
    merged = dict(DEFAULT_RULES)
    if rules:
        merged.update(rules)
    # drop mesh axes that don't exist on this mesh (e.g. 'pod' on single-pod)
    axes = set(mesh.axis_names)

    def filt(v):
        if v is None:
            return None
        if isinstance(v, str):
            return v if v in axes else None
        got = tuple(a for a in v if a in axes)
        return got if got else None

    merged = {k: filt(v) for k, v in merged.items()}
    prev_rules, prev_mesh = _rules(), _mesh()
    _state.rules, _state.mesh = merged, mesh
    try:
        yield
    finally:
        _state.rules, _state.mesh = prev_rules, prev_mesh


def logical_spec(names: Tuple[Optional[str], ...]) -> P:
    rules = _rules() or DEFAULT_RULES
    return P(*[rules.get(n) if n else None for n in names])


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def divisible_spec(spec: P, shape, mesh: Mesh) -> P:
    """Drop mesh axes from dims they don't divide (uneven shards unsupported)."""
    out = []
    for dim, axes in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if axes is None:
            out.append(None)
        elif dim % _axis_size(mesh, axes) == 0:
            out.append(axes)
        else:
            out.append(None)
    return P(*out)


def lshard(x, names: Tuple[Optional[str], ...]):
    """Constrain activation sharding by logical axis names (no-op w/o mesh).

    Understands the leading jet axis of collapsed bundles: when ``x`` has
    exactly one more dimension than ``names``, the extra *leading* axis is
    taken to be the R direction axis of an (R, …) stacked jet coefficient
    and bound to the ``"jet"`` rule (replicated — see ``DEFAULT_RULES``),
    with ``names`` binding the trailing primal dims. Model code annotated
    for primal shapes therefore keeps its data-parallel constraints when the
    collapsed interpreter replays it coefficient-wise on (R, B, S, D)
    bundles — the batch axis stays sharded over ('pod', 'data'), R stays
    whole on every device.
    """
    mesh = _mesh()
    if mesh is None:
        return x
    ndim = getattr(x, "ndim", len(getattr(x, "shape", ())))
    if ndim == len(names) + 1:
        names = ("jet",) + tuple(names)
    spec = divisible_spec(logical_spec(names), x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# parameter sharding by path
# ---------------------------------------------------------------------------

# (regex on '/'-joined param path, logical axes per dim). First match wins.
# FSDP shards the non-TP dimension of every matmul weight over (pod, data);
# TP shards heads/mlp/experts over 'model'.
PARAM_RULES = [
    (r"embed/embedding", ("vocab", "fsdp")),
    (r"(lm_head|output)/kernel", ("fsdp", "vocab")),
    (r"(wq|wk|wv|q_proj|k_proj|v_proj)/kernel", ("fsdp", "heads", "head_dim")),
    (r"(wq|wk|wv|q_proj|k_proj|v_proj)/bias", ("heads", "head_dim")),
    (r"(wo|o_proj)/kernel", ("heads", "head_dim", "fsdp")),
    (r"(wo|o_proj)/bias", ("embed",)),
    (r"(w_in|w_gate|wi|up_proj|gate_proj)/kernel", ("fsdp", "mlp")),
    (r"(w_out|wo_mlp|down_proj)/kernel", ("mlp", "fsdp")),
    (r"experts/(w_in|w_gate)", ("experts", "fsdp", "expert_mlp")),
    (r"experts/w_out", ("experts", "expert_mlp", "fsdp")),
    (r"router/kernel", ("fsdp", "experts")),
    # recurrent (RG-LRU) blocks: width dim is TP-sharded end to end
    (r"(x_branch|gate_branch|a_gate|i_gate)/kernel", ("fsdp", "mlp")),
    (r"(a_gate|i_gate)/bias", ("mlp",)),
    (r"rec/out/kernel", ("mlp", "fsdp")),
    (r"a_param", ("mlp",)),
    (r"conv_w", (None, "mlp")),
    (r"conv_b", ("mlp",)),
    (r"(norm|scale|ln|layernorm)", None),  # small vectors: replicated
    (r"(gate_w|gate_b)", None),
    (r"bias", None),
]


def param_logical_axes(path: str, ndim: int):
    for pat, axes in PARAM_RULES:
        if re.search(pat, path):
            if axes is None:
                return (None,) * ndim
            if len(axes) == ndim:
                return axes
            if len(axes) == ndim - 1:  # stacked-over-layers leading axis
                return (None,) + tuple(axes)
            if len(axes) < ndim:
                return (None,) * (ndim - len(axes)) + tuple(axes)
            return tuple(axes)[-ndim:] if ndim else ()
    return (None,) * ndim


def param_spec(path: str, ndim: int) -> P:
    return logical_spec(param_logical_axes(path, ndim))


def param_shardings(mesh: Mesh, params, rules: Optional[dict] = None):
    """NamedSharding pytree for a parameter pytree (paths joined with '/')."""
    flat, tree = jax.tree_util.tree_flatten_with_path(params)

    def path_str(kp):
        out = []
        for k in kp:
            if hasattr(k, "key"):
                out.append(str(k.key))
            elif hasattr(k, "idx"):
                out.append(str(k.idx))
            else:
                out.append(str(k))
        return "/".join(out)

    with activate(mesh, rules):
        specs = [
            NamedSharding(
                mesh,
                divisible_spec(
                    param_spec(path_str(kp), getattr(v, "ndim", 0)),
                    getattr(v, "shape", ()),
                    mesh,
                ),
            )
            for kp, v in flat
        ]
    return jax.tree_util.tree_unflatten(tree, specs)


def auto_spec(shape, mesh: Mesh, batch_dim: Optional[int] = 1,
              batch_axes=("pod", "data"), model_axis="model",
              jet_dim: Optional[int] = None) -> P:
    """Heuristic sharding for state pytrees (KV caches, recurrent states):
    shard `batch_dim` over the data axes if divisible, then the largest
    remaining dim over the model axis.

    ``jet_dim`` marks the direction axis R of a collapsed jet bundle — that
    dim is excluded from the model-axis candidates (it must stay whole on
    every device; the fused kernels grid over it). For the canonical
    (R, B, S, D) bundle layout use ``auto_spec(shape, mesh, batch_dim=1,
    jet_dim=0)`` — equivalently :func:`bundle_spec`."""
    axes = set(mesh.axis_names)
    batch_axes = tuple(a for a in batch_axes if a in axes)
    spec: list = [None] * len(shape)
    if jet_dim is not None and jet_dim == batch_dim:
        raise ValueError(f"jet_dim and batch_dim both {jet_dim}: the jet "
                         f"axis is never sharded")
    if (
        batch_dim is not None
        and batch_dim < len(shape)
        and batch_axes
        and shape[batch_dim] % _axis_size(mesh, batch_axes) == 0
        and shape[batch_dim] >= _axis_size(mesh, batch_axes)
    ):
        spec[batch_dim] = batch_axes
    if model_axis in axes:
        m = mesh.shape[model_axis]
        cands = [
            (shape[i], i)
            for i in range(len(shape))
            if spec[i] is None and i != jet_dim
            and shape[i] % m == 0 and shape[i] >= m
        ]
        if cands:
            _, i = max(cands)
            spec[i] = model_axis
    return P(*spec)


def bundle_spec(shape, mesh: Mesh) -> P:
    """Sharding spec for a collapsed (R, B, …) jet-bundle coefficient:
    batch over the data axes, the jet axis replicated (see the ``"jet"``
    rule), trailing feature dims eligible for the model axis."""
    return auto_spec(shape, mesh, batch_dim=1, jet_dim=0)


def state_shardings(mesh: Mesh, state, batch_shardable: bool = True):
    def one(leaf):
        shape = getattr(leaf, "shape", ())
        if len(shape) <= 1:
            return NamedSharding(mesh, P())
        return NamedSharding(
            mesh, auto_spec(shape, mesh, batch_dim=1 if batch_shardable else None)
        )

    return jax.tree.map(one, state)
