"""shard_map-level collectives: compressed cross-pod gradient reduction.

``compressed_psum`` moves int8 payloads over the named (slow, inter-pod) axis
instead of fp32: per-shard absmax scales are all-gathered (tiny), payloads are
quantized, summed via integer psum, and dequantized with the max scale. Used
by the explicit-DP training mode; validated on 8 host devices in tests.

``compressed_psum_ef`` is the error-feedback variant the trainer uses for the
PDE-residual/gradient reductions: each shard keeps its local quantization
residual and adds it back into the next step's payload (1-bit-Adam family),
so the compressed reduction is unbiased over time. Bytes on the wire per
reduced element: 1 (int8) vs 4 (fp32) — see
``benchmarks/distributed_laplacian.py`` for the measured weak-scaling rows.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _shared_scale(x32, axis_name: str):
    """Mesh-wide absmax/127 scale (pmax over shards), guarded against the
    all-zero case — an absmax of 0 would turn the dequantize into 0/0 NaN."""
    amax = jnp.max(jnp.abs(x32))
    amax = jax.lax.pmax(amax, axis_name)
    return jnp.where(amax > 0, amax, 1.0) / 127.0


def compressed_psum(x, axis_name: str):
    """All-reduce(mean) of x over `axis_name`, transmitting int8."""
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    x32 = x.astype(jnp.float32)
    # agree on a shared scale (max over shards) so the integer sum is exact
    scale = _shared_scale(x32, axis_name)
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int32)
    total = jax.lax.psum(q, axis_name)
    return (total.astype(jnp.float32) * scale / n).astype(x.dtype)


def compressed_psum_ef(x, err, axis_name: str, ok=None):
    """Error-feedback :func:`compressed_psum`: returns ``(mean, new_err)``.

    ``err`` is this shard's float32 residual buffer from the previous step;
    the payload quantized this step is ``x + err``, and ``new_err`` is what
    the int8 round dropped locally. Over time the accumulated reduction is
    exact (the residual can never grow beyond one quantization step).

    ``ok`` (optional scalar bool, per shard) is the quarantine gate of the
    cross-shard non-finite consensus (see ``train/trainer.py``): a shard
    with ``ok=False`` contributes an all-zero payload to the integer psum,
    is excluded from the mean's denominator, and keeps its residual buffer
    untouched for the step. This must happen *before* quantization — a NaN
    payload cast to int32 is platform-defined garbage that dequantizes to a
    *finite* wrong gradient on every healthy shard, the silent-divergence
    failure mode the consensus layer exists to stop.
    """
    x32 = x.astype(jnp.float32) + err
    if ok is None:
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    else:
        x32 = jnp.where(ok, x32, 0.0)
        n = jnp.maximum(jax.lax.psum(ok.astype(jnp.float32), axis_name), 1.0)
    scale = _shared_scale(x32, axis_name)
    q = jnp.clip(jnp.round(x32 / scale), -127, 127)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    new_err = x32 - q * scale
    if ok is not None:
        new_err = jnp.where(ok, new_err, err)  # quarantined: residual carries
    mean = (total.astype(jnp.float32) * scale / n).astype(x.dtype)
    return mean, new_err


def psum_mean(x, axis_name: str):
    return jax.lax.pmean(x, axis_name)


def masked_psum_mean(x, axis_name: str, ok):
    """Mean of ``x`` over the shards where ``ok`` (scalar bool, per shard)
    is True: quarantined shards contribute zero and are excluded from the
    denominator. All-shards-quarantined returns 0 (the caller's consensus
    gate skips the step before the value matters)."""
    n_ok = jax.lax.psum(ok.astype(jnp.float32), axis_name)
    total = jax.lax.psum(
        jnp.where(ok, x.astype(jnp.float32), 0.0), axis_name)
    return (total / jnp.maximum(n_ok, 1.0)).astype(x.dtype)
