"""shard_map-level collectives: compressed cross-pod gradient reduction.

``compressed_psum`` moves int8 payloads over the named (slow, inter-pod) axis
instead of fp32: per-shard absmax scales are all-gathered (tiny), payloads are
quantized, summed via integer psum, and dequantized with the max scale. Used
by the explicit-DP training mode; validated on 8 host devices in tests.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def compressed_psum(x, axis_name: str):
    """All-reduce(mean) of x over `axis_name`, transmitting int8."""
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    scale = jnp.maximum(jnp.max(jnp.abs(x.astype(jnp.float32))), 1e-12) / 127.0
    # agree on a shared scale (max over shards) so the integer sum is exact
    scale = jax.lax.pmax(scale, axis_name)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int32)
    total = jax.lax.psum(q, axis_name)
    return (total.astype(jnp.float32) * scale / n).astype(x.dtype)


def psum_mean(x, axis_name: str):
    return jax.lax.pmean(x, axis_name)
