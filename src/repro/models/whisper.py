"""Whisper-base: encoder-decoder transformer, conv frontend STUBBED.

Per the assignment, ``input_specs()`` provides precomputed frame embeddings
(B, encoder_seq, d_model) — the mel+conv frontend is out of scope. The
encoder is bidirectional (LayerNorm, GELU); the decoder has causal self-attn
+ cross-attn to the encoder output. Decode shapes run the decoder with a
static KV cache and precomputed cross-attention K/V held in the state.
"""

from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.distributed.sharding import lshard
from repro.models import layers as L


def _sinusoids(length, channels):
    half = channels // 2
    t = jnp.arange(length)[:, None]
    inv = jnp.exp(-math.log(10_000.0) * jnp.arange(half) / (half - 1))
    ang = t * inv[None]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _xattn_init(key, cfg):
    ks = jax.random.split(key, 4)
    dh = cfg.head_dim_
    return {
        "wq": {"kernel": L.trunc_normal(ks[0], (cfg.d_model, cfg.num_heads, dh),
                                        cfg.params_dtype)},
        "wk": {"kernel": L.trunc_normal(ks[1], (cfg.d_model, cfg.num_kv_heads, dh),
                                        cfg.params_dtype)},
        "wv": {"kernel": L.trunc_normal(ks[2], (cfg.d_model, cfg.num_kv_heads, dh),
                                        cfg.params_dtype)},
        "wo": {"kernel": L.trunc_normal(ks[3], (cfg.num_heads, dh, cfg.d_model),
                                        cfg.params_dtype)},
    }


def cross_kv(params, ctx):
    k = jnp.einsum("btd,dhk->bthk", ctx, params["wk"]["kernel"].astype(ctx.dtype))
    v = jnp.einsum("btd,dhk->bthk", ctx, params["wv"]["kernel"].astype(ctx.dtype))
    return k, v


def cross_attention(params, x, k, v):
    """q from x (B,S,D); k/v precomputed from context (B,T,Hkv,dh)."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"]["kernel"].astype(x.dtype))
    out = L.flash_attention(q, k, v, causal=False, chunk=min(512, k.shape[1]))
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"]["kernel"].astype(x.dtype))


def _enc_layer_init(key, cfg):
    ka, km = jax.random.split(key)
    return {
        "attn_norm": L.layernorm_init(cfg.d_model, cfg.params_dtype),
        "attn": L.attention_init(ka, cfg),
        "mlp_norm": L.layernorm_init(cfg.d_model, cfg.params_dtype),
        "mlp": L.mlp_init(km, cfg.d_model, cfg.d_ff, cfg.params_dtype, "gelu"),
    }


def _dec_layer_init(key, cfg):
    ka, kx, km = jax.random.split(key, 3)
    return {
        "attn_norm": L.layernorm_init(cfg.d_model, cfg.params_dtype),
        "attn": L.attention_init(ka, cfg),
        "xattn_norm": L.layernorm_init(cfg.d_model, cfg.params_dtype),
        "xattn": _xattn_init(kx, cfg),
        "mlp_norm": L.layernorm_init(cfg.d_model, cfg.params_dtype),
        "mlp": L.mlp_init(km, cfg.d_model, cfg.d_ff, cfg.params_dtype, "gelu"),
    }


def init(key, cfg) -> Dict[str, Any]:
    ks = jax.random.split(key, 5)
    n_enc = cfg.encoder_layers or cfg.num_layers
    n_dec = cfg.decoder_layers or cfg.num_layers
    enc = jax.vmap(lambda k: _enc_layer_init(k, cfg))(jax.random.split(ks[0], n_enc))
    dec = jax.vmap(lambda k: _dec_layer_init(k, cfg))(jax.random.split(ks[1], n_dec))
    return {
        "enc_layers": enc,
        "enc_norm": L.layernorm_init(cfg.d_model, cfg.params_dtype),
        "dec_layers": dec,
        "dec_norm": L.layernorm_init(cfg.d_model, cfg.params_dtype),
        "embed": {
            "embedding": L.trunc_normal(ks[2], (cfg.padded_vocab, cfg.d_model),
                                        cfg.params_dtype)
        },
        "pos_embed": L.trunc_normal(ks[3], (cfg.max_target_positions, cfg.d_model),
                                    cfg.params_dtype, std=0.01),
    }


def _mask_padded_vocab(logits, cfg):
    if cfg.padded_vocab > cfg.vocab_size:
        ids = jax.lax.broadcasted_iota(jnp.int32, (1, 1, cfg.padded_vocab), 2)
        logits = jnp.where(ids < cfg.vocab_size, logits, -1e30)
    return logits


def encode(params, frames, cfg):
    """frames: (B, T, D) stubbed embeddings -> encoder states."""
    x = frames.astype(cfg.compute_dtype)
    x = x + _sinusoids(x.shape[1], cfg.d_model).astype(x.dtype)
    x = lshard(x, ("batch", "seq", "embed"))
    positions = jnp.arange(x.shape[1])

    def body(carry, layer):
        y = carry
        h = L.layernorm(layer["attn_norm"], y)
        h = L.attention_layer(layer["attn"], h, cfg, positions=positions, causal=False)
        y = y + h
        h = L.layernorm(layer["mlp_norm"], y)
        return y + L.mlp(layer["mlp"], h, "gelu"), ()

    body = L.remat_block(body, cfg)
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return L.layernorm(params["enc_norm"], x)


def decode_train(params, tokens, enc_out, cfg):
    x = jnp.take(params["embed"]["embedding"], tokens, axis=0).astype(cfg.compute_dtype)
    S = tokens.shape[1]
    pos = jnp.arange(S)
    pe = jnp.take(params["pos_embed"], jnp.minimum(pos, params["pos_embed"].shape[0] - 1),
                  axis=0)
    x = x + pe.astype(x.dtype)
    x = lshard(x, ("batch", "seq", "embed"))

    def body(carry, layer):
        y = carry
        h = L.layernorm(layer["attn_norm"], y)
        h = L.attention_layer(layer["attn"], h, cfg, positions=pos, causal=True)
        y = y + h
        h = L.layernorm(layer["xattn_norm"], y)
        k, v = cross_kv(layer["xattn"], enc_out)
        y = y + cross_attention(layer["xattn"], h, k, v)
        h = L.layernorm(layer["mlp_norm"], y)
        return y + L.mlp(layer["mlp"], h, "gelu"), ()

    body = L.remat_block(body, cfg)
    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    x = L.layernorm(params["dec_norm"], x)
    logits = jnp.einsum("bsd,vd->bsv", x,
                        params["embed"]["embedding"].astype(cfg.compute_dtype))
    logits = _mask_padded_vocab(logits, cfg)
    return lshard(logits, ("batch", "seq", "vocab"))


def forward(params, batch, cfg):
    enc_out = encode(params, batch["frames"], cfg)
    return decode_train(params, batch["tokens"], enc_out, cfg), jnp.zeros(())


def loss(params, batch, cfg):
    from repro.models.transformer import lm_loss

    logits, aux = forward(params, batch, cfg)
    return lm_loss(logits, batch["tokens"], aux, real_vocab=cfg.vocab_size)


# --- serving ----------------------------------------------------------------


def init_decode_state(cfg, batch, max_len, dtype):
    n_dec = cfg.decoder_layers or cfg.num_layers
    dh = cfg.head_dim_
    self_cache = jax.tree.map(
        lambda a: jnp.broadcast_to(a, (n_dec,) + a.shape),
        L.attention_cache_init(cfg, batch, max_len, dtype),
    )
    T = cfg.encoder_seq
    cross = {
        "k": jnp.zeros((n_dec, batch, T, cfg.num_kv_heads, dh), dtype),
        "v": jnp.zeros((n_dec, batch, T, cfg.num_kv_heads, dh), dtype),
    }
    return {"self": self_cache, "cross": cross, "pos": jnp.zeros((batch,), jnp.int32)}


def prefill_cross(params, state, frames, cfg):
    """Run the encoder once and fill the cross-attention K/V."""
    enc_out = encode(params, frames, cfg)

    def body(_, layer):
        k, v = cross_kv(layer["xattn"], enc_out)
        return (), (k, v)

    _, (ks, vs) = jax.lax.scan(body, (), params["dec_layers"])
    state = dict(state)
    state["cross"] = {"k": ks, "v": vs}
    return state


def decode_step(params, state, tokens, cfg):
    pos = state["pos"]
    x = jnp.take(params["embed"]["embedding"], tokens[:, None], axis=0).astype(cfg.compute_dtype)
    pe = jnp.take(params["pos_embed"],
                  jnp.minimum(pos, params["pos_embed"].shape[0] - 1), axis=0)
    x = x + pe[:, None].astype(x.dtype)

    def body(carry, layer_and_cache):
        y = carry
        layer, sc, ck, cv = layer_and_cache
        h = L.layernorm(layer["attn_norm"], y)
        h, new_sc = L.attention_decode(layer["attn"], h, sc, pos, cfg, use_rope=False)
        y = y + h
        h = L.layernorm(layer["xattn_norm"], y)
        q = jnp.einsum("bsd,dhk->bshk", h, layer["xattn"]["wq"]["kernel"].astype(h.dtype))
        o = L.cached_attention(layer["xattn"], q, ck, cv, pos, mask_by_pos=False)
        y = y + o
        h = L.layernorm(layer["mlp_norm"], y)
        return y + L.mlp(layer["mlp"], h, "gelu"), new_sc

    x, new_self = jax.lax.scan(
        body, x, (params["dec_layers"], state["self"],
                  state["cross"]["k"], state["cross"]["v"])
    )
    x = L.layernorm(params["dec_norm"], x)
    logits = jnp.einsum("bsd,vd->bsv", x,
                        params["embed"]["embedding"].astype(cfg.compute_dtype))
    logits = _mask_padded_vocab(logits, cfg)[:, 0]
    return logits, {"self": new_self, "cross": state["cross"], "pos": pos + 1}


def input_specs(cfg, shape_cfg):
    B, S = shape_cfg.global_batch, shape_cfg.seq_len
    if shape_cfg.kind in ("train", "prefill"):
        return {
            "frames": jax.ShapeDtypeStruct((B, cfg.encoder_seq, cfg.d_model),
                                           cfg.compute_dtype),
            "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
        }
    return {"tokens": jax.ShapeDtypeStruct((B,), jnp.int32)}
