"""Decoder-only transformer LM (dense + MoE families).

Covers: mistral-large-123b, yi-6b, qwen2-1.5b, llama3.2-3b (dense) and
deepseek-moe-16b, arctic-480b (MoE: shared experts / dense residual /
first-k-dense-layers supported).

Layers are stacked along a leading axis and applied with ``jax.lax.scan`` so
the lowered HLO is O(1) in depth (88-layer mistral-large and 100-layer
llama-vision compile in seconds). Optional ``first_dense_layers`` are kept as
a separately-stacked prefix scan.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.distributed.sharding import lshard
from repro.models import layers as L


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _layer_init(key, cfg, moe_layer: bool):
    ka, km, k1, k2 = jax.random.split(key, 4)
    p = {
        "attn_norm": L.rmsnorm_init(cfg.d_model, cfg.params_dtype),
        "attn": L.attention_init(ka, cfg),
        "mlp_norm": L.rmsnorm_init(cfg.d_model, cfg.params_dtype),
    }
    if moe_layer:
        p["moe"] = L.moe_init(km, cfg, cfg.params_dtype)
    else:
        p["mlp"] = L.mlp_init(km, cfg.d_model, cfg.d_ff, cfg.params_dtype, cfg.act)
    return p


def init(key, cfg) -> Dict[str, Any]:
    keys = jax.random.split(key, 4)
    n_prefix = cfg.first_dense_layers if cfg.num_experts else 0
    n_main = cfg.num_layers - n_prefix
    moe_main = cfg.num_experts > 0

    main_keys = jax.random.split(keys[0], n_main)
    layers = jax.vmap(lambda k: _layer_init(k, cfg, moe_main))(main_keys)
    params = {
        "embed": {
            "embedding": L.trunc_normal(keys[1], (cfg.padded_vocab, cfg.d_model),
                                        cfg.params_dtype)
        },
        "layers": layers,
        "final_norm": L.rmsnorm_init(cfg.d_model, cfg.params_dtype),
    }
    if n_prefix:
        pk = jax.random.split(keys[2], n_prefix)
        params["prefix_layers"] = jax.vmap(lambda k: _layer_init(k, cfg, False))(pk)
    if not cfg.tied_embeddings:
        params["lm_head"] = {
            "kernel": L.trunc_normal(keys[3], (cfg.d_model, cfg.padded_vocab),
                                     cfg.params_dtype)
        }
    return params


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------


def _block(layer, x, cfg, positions, moe_layer: bool):
    h = L.rmsnorm(layer["attn_norm"], x, cfg.norm_eps)
    h = L.attention_layer(layer["attn"], h, cfg, positions=positions,
                          causal=True, window=cfg.sliding_window)
    x = x + h
    h = L.rmsnorm(layer["mlp_norm"], x, cfg.norm_eps)
    if moe_layer:
        h, aux = L.moe(layer["moe"], h, cfg)
    else:
        h, aux = L.mlp(layer["mlp"], h, cfg.act), jnp.zeros(())
    x = x + h
    x = lshard(x, ("batch", "residual_seq", "embed"))
    return x, aux


def _scan_blocks(stacked, x, cfg, positions, moe_layer: bool):
    def body(carry, layer):
        y, aux = _block(layer, carry, cfg, positions, moe_layer)
        return y, aux

    body = L.remat_block(body, cfg)
    x, auxs = jax.lax.scan(body, x, stacked)
    return x, auxs.sum()


def embed_tokens(params, tokens, cfg):
    emb = params["embed"]["embedding"]
    x = jnp.take(emb, tokens, axis=0).astype(cfg.compute_dtype)
    return lshard(x, ("batch", "seq", "embed"))


def _unrolled_blocks(stacked, x, cfg, positions, moe_layer: bool):
    def unstack(stacked):
        n = jax.tree.leaves(stacked)[0].shape[0]
        return [jax.tree.map(lambda a: a[i], stacked) for i in range(n)]

    aux = jnp.zeros(())
    for layer in unstack(stacked):
        x, a = _block(layer, x, cfg, positions, moe_layer)
        aux += a
    return x, aux


def backbone(params, x, cfg, positions, *, unroll: bool = False):
    """Embeddings -> final hidden states. ``x``: (B, S, D) continuous inputs
    (also the entry point for the differential-operator heads).

    The scanned layer stack is the *fusing default* for differential-operator
    heads (transformer PINNs / operator learning with
    ``cfg.attn_impl='reference'``): the recursive offload engine
    (:mod:`repro.core.offload`) plans the scan body once per (K, signature)
    and fuses its segments on every iteration under
    ``operators.<op>(..., method='collapsed', backend='pallas')``. Each
    layer's whole attention block — q/k/v projections (+ ``cfg.qkv_bias``
    biases and rotary embeddings under the LM default
    ``cfg.use_rope=True``), (GQA, via ``cfg.num_kv_heads <
    cfg.num_heads``) attention, output projection — fuses as ONE
    superblock kernel; ``cfg.use_rope=False`` (the PINN convention)
    likewise. ``unroll=True`` unrolls the stack in Python instead —
    O(depth) jaxpr size; kept for unroll-vs-scan benchmarks
    (``benchmarks/scan_depth.py``).
    """
    blocks = _unrolled_blocks if unroll else _scan_blocks
    aux = jnp.zeros(())
    if "prefix_layers" in params:
        x, a = blocks(params["prefix_layers"], x, cfg, positions, False)
        aux += a
    x, a = blocks(params["layers"], x, cfg, positions, cfg.num_experts > 0)
    aux += a
    return L.rmsnorm(params["final_norm"], x, cfg.norm_eps), aux


def backbone_unrolled(params, x, cfg, positions):
    """Thin compatibility alias for ``backbone(..., unroll=True)``.

    Historically the only fusing path for collapsed-Taylor operators
    (``lax.scan`` bodies used to fall back to the CRULES interpreter); the
    recursive offload engine made the scanned :func:`backbone` the fusing
    default, so this survives only for callers that want the unrolled jaxpr
    (e.g. depth-scaling benchmarks)."""
    return backbone(params, x, cfg, positions, unroll=True)


def unembed(params, x, cfg):
    if cfg.tied_embeddings:
        kern = params["embed"]["embedding"].T
    else:
        kern = params["lm_head"]["kernel"]
    logits = jnp.einsum("bsd,dv->bsv", x, kern.astype(cfg.compute_dtype))
    if cfg.padded_vocab > cfg.vocab_size:  # mask padded rows (never sampled)
        ids = jax.lax.broadcasted_iota(jnp.int32, (1, 1, cfg.padded_vocab), 2)
        logits = jnp.where(ids < cfg.vocab_size, logits, -1e30)
    return lshard(logits, ("batch", "seq", "vocab"))


def forward(params, batch, cfg):
    tokens = batch["tokens"]
    positions = jnp.arange(tokens.shape[1])
    x = embed_tokens(params, tokens, cfg)
    x, aux = backbone(params, x, cfg, positions)
    return unembed(params, x, cfg), aux


def loss(params, batch, cfg):
    logits, aux = forward(params, batch, cfg)
    return lm_loss(logits, batch["tokens"], aux, real_vocab=cfg.vocab_size)


def lm_loss(logits, tokens, aux=0.0, z_coeff=1e-4, aux_coeff=1e-2,
            real_vocab=None):
    """Shifted causal cross-entropy (fp32) + z-loss + MoE aux loss.

    The gold logit is extracted with a masked reduction instead of
    ``take_along_axis`` so a vocab-sharded logits tensor is never
    all-gathered (a gather along the sharded vocab dim forces replication
    under GSPMD). ``real_vocab`` masks padded vocab rows (padded embeddings
    keep the vocab axis divisible by the model-parallel degree).
    """
    logits = logits[:, :-1].astype(jnp.float32)
    targets = tokens[:, 1:]
    V = logits.shape[-1]
    vocab_ids = jax.lax.broadcasted_iota(jnp.int32, (1, 1, V), 2)
    if real_vocab is not None and real_vocab < V:
        logits = jnp.where(vocab_ids < real_vocab, logits, -1e30)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold_mask = vocab_ids == targets[..., None]
    gold = jnp.sum(jnp.where(gold_mask, logits, 0.0), axis=-1)
    nll = (lse - gold).mean()
    zloss = (lse**2).mean()
    total = nll + z_coeff * zloss + aux_coeff * aux
    return total, {"nll": nll, "zloss": zloss, "aux": aux}


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def init_decode_state(cfg, batch, max_len, dtype):
    n_prefix = cfg.first_dense_layers if cfg.num_experts else 0
    n_main = cfg.num_layers - n_prefix

    def stack(n):
        cache = L.attention_cache_init(cfg, batch, max_len, dtype)
        return jax.tree.map(lambda a: jnp.broadcast_to(a, (n,) + a.shape), cache)

    state = {"layers": stack(n_main), "pos": jnp.zeros((batch,), jnp.int32)}
    if n_prefix:
        state["prefix_layers"] = stack(n_prefix)
    return state


def _decode_scan(layers_params, caches, x, pos, cfg, moe_main):
    """Scan over layers with the stacked KV cache held in the CARRY.

    A cache passed as scan xs->ys allocates fresh output buffers every step;
    as a carry, XLA updates the while-loop buffer in place — per-device HBM
    for decode drops to (params + one cache) instead of ~3x the cache.
    """

    def body(carry, layer):
        x, caches, i = carry
        cache_i = jax.tree.map(
            lambda c: jax.lax.dynamic_index_in_dim(c, i, 0, keepdims=False), caches
        )
        h = L.rmsnorm(layer["attn_norm"], x, cfg.norm_eps)
        h, new_cache = L.attention_decode(
            layer["attn"], h, cache_i, pos, cfg, window=cfg.sliding_window
        )
        x = x + h
        h = L.rmsnorm(layer["mlp_norm"], x, cfg.norm_eps)
        if moe_main and "moe" in layer:
            h, _ = L.moe(layer["moe"], h, cfg)
        else:
            h = L.mlp(layer["mlp"], h, cfg.act)
        caches = jax.tree.map(
            lambda c, nc: jax.lax.dynamic_update_index_in_dim(c, nc, i, 0),
            caches, new_cache,
        )
        return (x + h, caches, i + 1), ()

    (x, caches, _), _ = jax.lax.scan(
        body, (x, caches, jnp.zeros((), jnp.int32)), layers_params
    )
    return x, caches


def decode_step(params, state, tokens, cfg):
    """tokens: (B,) int32 -> (logits (B, V), new state). One cache step.

    state["pos"] is (B,): per-slot positions (continuous batching)."""
    pos = state["pos"]
    x = embed_tokens(params, tokens[:, None], cfg)
    moe_main = cfg.num_experts > 0

    new_state = dict(state)
    if "prefix_layers" in params:
        x, new_state["prefix_layers"] = _decode_scan(
            params["prefix_layers"], state["prefix_layers"], x, pos, cfg, False
        )
    x, new_state["layers"] = _decode_scan(
        params["layers"], state["layers"], x, pos, cfg, moe_main
    )
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params, x, cfg)[:, 0]
    new_state["pos"] = pos + 1
    return logits, new_state


# ---------------------------------------------------------------------------
# dry-run input specs
# ---------------------------------------------------------------------------


def input_specs(cfg, shape_cfg):
    B, S = shape_cfg.global_batch, shape_cfg.seq_len
    tok = jax.ShapeDtypeStruct((B, S), jnp.int32)
    if shape_cfg.kind in ("train", "prefill"):
        return {"tokens": tok}
    return {"tokens": jax.ShapeDtypeStruct((B,), jnp.int32)}
