"""RecurrentGemma / Griffin: RG-LRU recurrent blocks + local attention, 1:2.

Block pattern (R, R, A): two residual recurrent blocks per local-attention
block (window = 2048). The RG-LRU linear recurrence

    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t),
    a_t = exp(-c * softplus(Lambda) * sigma(W_a x_t))

is evaluated with ``jax.lax.associative_scan`` for train/prefill (log-depth,
shardable over the sequence axis — this is the sub-quadratic arch that runs
the ``long_500k`` cell) and with a single fused step for decode (state =
(h, conv window): no KV cache growth).

38 layers = 12 stacked (R,R,A) superblocks (scanned) + a trailing (R,R).
"""

from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.distributed.sharding import lshard
from repro.models import layers as L

_C = 8.0  # RG-LRU decay sharpness constant (Griffin)


# ---------------------------------------------------------------------------
# RG-LRU + recurrent block
# ---------------------------------------------------------------------------


def rglru_init(key, width, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    # Lambda init so that a in [0.9, 0.999] at sigma(.)=0.5 (Griffin appendix)
    u = jax.random.uniform(k1, (width,), minval=0.9**2, maxval=0.999**2)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / _C))  # softplus^-1(-log u / c)
    return {
        "a_param": lam.astype(jnp.float32),
        "a_gate": L.dense_init(k2, width, width, dtype, bias=True),
        "i_gate": L.dense_init(k3, width, width, dtype, bias=True),
    }


def _rglru_coeffs(params, x):
    """Per-step decay a_t and input b_t for the linear recurrence."""
    r = jax.nn.sigmoid(L.dense(params["a_gate"], x).astype(jnp.float32))
    i = jax.nn.sigmoid(L.dense(params["i_gate"], x).astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(params["a_param"]) * r  # (B, S, W) fp32
    a = jnp.exp(log_a)
    gated = i * x.astype(jnp.float32)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * gated
    return a, b


def _combine(lhs, rhs):
    a1, b1 = lhs
    a2, b2 = rhs
    return a1 * a2, a2 * b1 + b2


@jax.custom_vjp
def _linrec(a, b):
    """h_t = a_t h_{t-1} + b_t over axis 1, h_0 = 0 (log-depth assoc. scan)."""
    _, h = jax.lax.associative_scan(_combine, (a, b), axis=1)
    return h


def _linrec_fwd(a, b):
    h = _linrec(a, b)
    # bf16 residuals: halves the dominant HBM term of recurrent train cells
    # (decay factors/states are magnitude-bounded; grads recomputed in f32)
    return h, (a.astype(jnp.bfloat16), h.astype(jnp.bfloat16))


def _linrec_bwd(res, gh):
    """Adjoint of a linear recurrence is the reversed linear recurrence:
        lam_t = gh_t + a_{t+1} lam_{t+1};  db_t = lam_t;  da_t = lam_t h_{t-1}.
    Saving only (a, h) and running one reverse scan keeps the backward O(S)
    memory — differentiating *through* the associative-scan tree materializes
    every tree level and dominated the recurrentgemma train-cell HBM."""
    a = res[0].astype(jnp.float32)
    h = res[1].astype(jnp.float32)
    gh = gh.astype(jnp.float32)
    a_next = jnp.concatenate([a[:, 1:], jnp.zeros_like(a[:, :1])], axis=1)
    ar = jnp.flip(a_next, axis=1)
    gr = jnp.flip(gh, axis=1)
    _, lam_r = jax.lax.associative_scan(_combine, (ar, gr), axis=1)
    lam = jnp.flip(lam_r, axis=1)
    h_prev = jnp.concatenate([jnp.zeros_like(h[:, :1]), h[:, :-1]], axis=1)
    return lam * h_prev, lam


_linrec.defvjp(_linrec_fwd, _linrec_bwd)


def rglru(params, x, h0=None):
    """x: (B, S, W) -> (y, h_last). Associative scan over time."""
    a, b = _rglru_coeffs(params, x)
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))
    h = _linrec(a, b)
    return h.astype(x.dtype), h[:, -1]


def rglru_step(params, x, h):
    """Single decode step. x: (B, 1, W), h: (B, W) -> (y, h_new)."""
    a, b = _rglru_coeffs(params, x)
    h_new = a[:, 0] * h.astype(jnp.float32) + b[:, 0]
    return h_new[:, None].astype(x.dtype), h_new


def conv1d_init(key, width, kernel, dtype):
    return {
        "conv_w": L.trunc_normal(key, (kernel, width), dtype, std=1.0 / math.sqrt(kernel)),
        "conv_b": jnp.zeros((width,), dtype),
    }


def causal_conv1d(params, x):
    """Depthwise causal conv via shifted adds (keeps jet rules trivial)."""
    w = params["conv_w"].astype(x.dtype)
    K = w.shape[0]
    out = x * w[K - 1]
    for i in range(1, K):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, : x.shape[1]]
        out = out + shifted * w[K - 1 - i]
    return out + params["conv_b"].astype(x.dtype)


def causal_conv1d_step(params, x, window):
    """x: (B, 1, W); window: (B, K-1, W) previous inputs -> (y, new_window)."""
    w = params["conv_w"].astype(x.dtype)
    K = w.shape[0]
    buf = jnp.concatenate([window, x], axis=1)  # (B, K, W)
    y = jnp.einsum("bkw,kw->bw", buf, w)[:, None] + params["conv_b"].astype(x.dtype)
    return y, buf[:, 1:]


def recurrent_block_init(key, cfg, dtype):
    W = cfg.lru_width or cfg.d_model
    ks = jax.random.split(key, 5)
    return {
        "gate_branch": L.dense_init(ks[0], cfg.d_model, W, dtype),
        "x_branch": L.dense_init(ks[1], cfg.d_model, W, dtype),
        "conv": conv1d_init(ks[2], W, cfg.rglru_conv_width, dtype),
        "rglru": rglru_init(ks[3], W, dtype),
        "out": L.dense_init(ks[4], W, cfg.d_model, dtype),
    }


def recurrent_block(params, x, cfg):
    # the whole recurrent pipeline is elementwise in the width dim: shard it
    # over the TP axis so every (B, S, W) gate/state tensor is W/16 per chip
    gate = jax.nn.gelu(L.dense(params["gate_branch"], x))
    gate = lshard(gate, ("batch", "seq", "mlp"))
    u = L.dense(params["x_branch"], x)
    u = lshard(u, ("batch", "seq", "mlp"))
    u = causal_conv1d(params["conv"], u)
    u = lshard(u, ("batch", "seq", "mlp"))
    u, _ = rglru(params["rglru"], u)
    u = lshard(u, ("batch", "seq", "mlp"))
    return L.dense(params["out"], u * gate)


def recurrent_block_step(params, x, state, cfg):
    gate = jax.nn.gelu(L.dense(params["gate_branch"], x))
    u = L.dense(params["x_branch"], x)
    u, conv_win = causal_conv1d_step(params["conv"], u, state["conv"])
    u, h = rglru_step(params["rglru"], u, state["h"])
    y = L.dense(params["out"], u * gate)
    return y, {"conv": conv_win, "h": h}


# ---------------------------------------------------------------------------
# model
# ---------------------------------------------------------------------------


def _layer_init(key, cfg, kind):
    ka, km = jax.random.split(key)
    p = {
        "pre_norm": L.rmsnorm_init(cfg.d_model, cfg.params_dtype),
        "mlp_norm": L.rmsnorm_init(cfg.d_model, cfg.params_dtype),
        "mlp": L.mlp_init(km, cfg.d_model, cfg.d_ff, cfg.params_dtype, "gelu"),
    }
    if kind == "A":
        p["attn"] = L.attention_init(ka, cfg)
    else:
        p["rec"] = recurrent_block_init(ka, cfg, cfg.params_dtype)
    return p


def _superblock_init(key, cfg):
    ks = jax.random.split(key, len(cfg.block_pattern))
    return [_layer_init(k, cfg, kind) for k, kind in zip(ks, cfg.block_pattern)]


def init(key, cfg) -> Dict[str, Any]:
    pat = cfg.block_pattern or ("R", "R", "A")
    n_super, n_rem = divmod(cfg.num_layers, len(pat))
    keys = jax.random.split(key, 4)
    sk = jax.random.split(keys[0], n_super)
    supers = jax.vmap(lambda k: _as_dict(_superblock_init(k, cfg)))(sk)
    params = {
        "embed": {
            "embedding": L.trunc_normal(keys[1], (cfg.padded_vocab, cfg.d_model),
                                        cfg.params_dtype)
        },
        "supers": supers,
        "final_norm": L.rmsnorm_init(cfg.d_model, cfg.params_dtype),
    }
    if n_rem:
        rk = jax.random.split(keys[2], n_rem)
        params["tail"] = [_layer_init(k, cfg, pat[i]) for i, k in enumerate(rk)]
    return params


def _as_dict(layer_list):
    return {str(i): p for i, p in enumerate(layer_list)}


def _apply_layer(layer, x, cfg, positions, kind):
    h = L.rmsnorm(layer["pre_norm"], x, cfg.norm_eps)
    if kind == "A":
        h = L.attention_layer(layer["attn"], h, cfg, positions=positions,
                              causal=True, window=cfg.sliding_window or 2048)
    else:
        h = recurrent_block(layer["rec"], h, cfg)
    x = x + h
    h = L.rmsnorm(layer["mlp_norm"], x, cfg.norm_eps)
    x = x + L.mlp(layer["mlp"], h, "gelu")
    return lshard(x, ("batch", "seq", "embed"))


def backbone(params, x, cfg, positions):
    pat = cfg.block_pattern or ("R", "R", "A")

    def body(carry, superblock):
        y = carry
        for i, kind in enumerate(pat):
            y = _apply_layer(superblock[str(i)], y, cfg, positions, kind)
        return y, ()

    body = L.remat_block(body, cfg)
    x, _ = jax.lax.scan(body, x, params["supers"])
    for i, layer in enumerate(params.get("tail", [])):
        x = _apply_layer(layer, x, cfg, positions, pat[i])
    return L.rmsnorm(params["final_norm"], x, cfg.norm_eps), jnp.zeros(())


def embed_tokens(params, tokens, cfg):
    emb = params["embed"]["embedding"]
    x = jnp.take(emb, tokens, axis=0).astype(cfg.compute_dtype)
    x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)  # gemma-style scaling
    return lshard(x, ("batch", "seq", "embed"))


def forward(params, batch, cfg):
    tokens = batch["tokens"]
    positions = jnp.arange(tokens.shape[1])
    x = embed_tokens(params, tokens, cfg)
    x, aux = backbone(params, x, cfg, positions)
    kern = params["embed"]["embedding"].T  # tied (gemma)
    logits = jnp.einsum("bsd,dv->bsv", x, kern.astype(cfg.compute_dtype))
    return lshard(logits, ("batch", "seq", "vocab")), aux


def loss(params, batch, cfg):
    from repro.models.transformer import lm_loss

    logits, aux = forward(params, batch, cfg)
    return lm_loss(logits, batch["tokens"], aux, real_vocab=cfg.vocab_size)


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def _layer_state(cfg, kind, batch, max_len, dtype):
    W = cfg.lru_width or cfg.d_model
    if kind == "A":
        window = min(cfg.sliding_window or 2048, max_len)
        return L.attention_cache_init(cfg, batch, window, dtype)
    return {
        "conv": jnp.zeros((batch, cfg.rglru_conv_width - 1, W), dtype),
        "h": jnp.zeros((batch, W), jnp.float32),
    }


def init_decode_state(cfg, batch, max_len, dtype):
    pat = cfg.block_pattern or ("R", "R", "A")
    n_super, n_rem = divmod(cfg.num_layers, len(pat))
    per_super = {
        str(i): _layer_state(cfg, kind, batch, max_len, dtype)
        for i, kind in enumerate(pat)
    }
    supers = jax.tree.map(
        lambda a: jnp.broadcast_to(a, (n_super,) + a.shape), per_super
    )
    state = {"supers": supers, "pos": jnp.zeros((batch,), jnp.int32)}
    if n_rem:
        state["tail"] = [
            _layer_state(cfg, pat[i], batch, max_len, dtype) for i in range(n_rem)
        ]
    return state


def _decode_layer(layer, x, st, pos, cfg, kind):
    h = L.rmsnorm(layer["pre_norm"], x, cfg.norm_eps)
    if kind == "A":
        window = cfg.sliding_window or 2048
        cache_len = st["k"].shape[1]
        # rotating per-slot write position for the windowed cache
        wpos = jnp.mod(pos, cache_len)  # (B,)
        q, k, v = L._proj_qkv(layer["attn"], h, cfg)
        q = L.rope(q, pos[:, None], cfg.rope_theta)
        k = L.rope(k, pos[:, None], cfg.rope_theta)
        ck = L.cache_insert(st["k"], k, wpos)
        cv = L.cache_insert(st["v"], v, wpos)
        slot_pos = jnp.arange(cache_len)
        slot_age = jnp.mod(wpos[:, None] - slot_pos[None], cache_len)  # (B, L)
        valid = slot_age <= jnp.minimum(pos, window - 1)[:, None]
        h = _windowed_cached(layer["attn"], q, ck, cv, valid)
        new_st = {"k": ck, "v": cv}
    else:
        h, new_st = recurrent_block_step(layer["rec"], h, st, cfg)
    x = x + h
    hm = L.rmsnorm(layer["mlp_norm"], x, cfg.norm_eps)
    x = x + L.mlp(layer["mlp"], hm, "gelu")
    return x, new_st


def _windowed_cached(attn_params, q, ck, cv, valid):
    B, _, Hq, dh = q.shape
    Hkv = ck.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, 1, Hkv, G, dh)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, ck, preferred_element_type=jnp.float32)
    s = s / math.sqrt(dh)
    s = jnp.where(valid[:, None, None, None, :], s, L.NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(cv.dtype), cv).reshape(B, 1, Hq, dh)
    return jnp.einsum("bshk,hkd->bsd", o, attn_params["wo"]["kernel"].astype(q.dtype))


def decode_step(params, state, tokens, cfg):
    pat = cfg.block_pattern or ("R", "R", "A")
    pos = state["pos"]
    x = embed_tokens(params, tokens[:, None], cfg)

    def body(carry, layer_and_state):
        y = carry
        layer, st = layer_and_state
        new_st = {}
        for i, kind in enumerate(pat):
            y, new_st[str(i)] = _decode_layer(layer[str(i)], y, st[str(i)], pos, cfg, kind)
        return y, new_st

    x, new_supers = jax.lax.scan(body, x, (params["supers"], state["supers"]))
    new_state = {"supers": new_supers, "pos": pos + 1}
    if "tail" in params:
        new_state["tail"] = []
        for i, layer in enumerate(params["tail"]):
            x, st = _decode_layer(layer, x, state["tail"][i], pos, cfg, pat[i])
            new_state["tail"].append(st)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    kern = params["embed"]["embedding"].T
    logits = jnp.einsum("bsd,dv->bsv", x, kern.astype(cfg.compute_dtype))[:, 0]
    return logits, new_state


def input_specs(cfg, shape_cfg):
    B, S = shape_cfg.global_batch, shape_cfg.seq_len
    if shape_cfg.kind in ("train", "prefill"):
        return {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    return {"tokens": jax.ShapeDtypeStruct((B,), jnp.int32)}
