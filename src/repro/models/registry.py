"""Model registry: family name -> module implementing the model protocol.

Every model module exposes:
  init(key, cfg) -> params
  forward(params, batch, cfg) -> logits        (training / prefill)
  loss(params, batch, cfg) -> (scalar, metrics)
  init_decode_state(cfg, batch, max_len, dtype) -> state
  decode_step(params, state, tokens, cfg) -> (logits, state)
  input_specs(cfg, shape_cfg) -> dict of ShapeDtypeStruct  (for the dry-run)
"""

from __future__ import annotations

from importlib import import_module

_FAMILY_TO_MODULE = {
    "dense": "repro.models.transformer",
    "moe": "repro.models.transformer",
    "hybrid": "repro.models.recurrentgemma",
    "ssm": "repro.models.xlstm",
    "audio": "repro.models.whisper",
    "vlm": "repro.models.vlm",
    "mlp": "repro.models.mlp",
}


def get_model(cfg):
    return import_module(_FAMILY_TO_MODULE[cfg.family])
