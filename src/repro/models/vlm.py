"""Llama-3.2-Vision-style VLM backbone: decoder-only text transformer with
gated cross-attention image layers inserted every `cross_attn_every` layers.

The vision tower is STUBBED per the assignment: ``input_specs()`` provides
precomputed patch embeddings (B, vision_tokens, vision_dim); a single linear
projects them into the text width. 100 layers = 20 scanned superblocks of
(cross_attn_every - 1) self-attn layers + 1 gated cross-attn layer.
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.distributed.sharding import lshard
from repro.models import layers as L
from repro.models import whisper as W  # reuse cross-attention pieces
from repro.models.transformer import lm_loss


def _self_layer_init(key, cfg):
    from repro.models.transformer import _layer_init

    return _layer_init(key, cfg, moe_layer=False)


def _cross_layer_init(key, cfg):
    kx, km = jax.random.split(key)
    return {
        "xattn_norm": L.rmsnorm_init(cfg.d_model, cfg.params_dtype),
        "xattn": W._xattn_init(kx, cfg),
        "attn_gate": jnp.zeros((), cfg.params_dtype),
        "mlp_norm": L.rmsnorm_init(cfg.d_model, cfg.params_dtype),
        "mlp": L.mlp_init(km, cfg.d_model, cfg.d_ff, cfg.params_dtype, cfg.act),
        "mlp_gate": jnp.zeros((), cfg.params_dtype),
    }


def _superblock_init(key, cfg):
    n_self = cfg.cross_attn_every - 1
    ks = jax.random.split(key, n_self + 1)
    p = {str(i): _self_layer_init(ks[i], cfg) for i in range(n_self)}
    p["cross"] = _cross_layer_init(ks[-1], cfg)
    return p


def init(key, cfg) -> Dict[str, Any]:
    assert cfg.num_layers % cfg.cross_attn_every == 0
    n_super = cfg.num_layers // cfg.cross_attn_every
    ks = jax.random.split(key, 4)
    supers = jax.vmap(lambda k: _superblock_init(k, cfg))(jax.random.split(ks[0], n_super))
    return {
        "embed": {
            "embedding": L.trunc_normal(ks[1], (cfg.padded_vocab, cfg.d_model),
                                        cfg.params_dtype)
        },
        "vision_proj": L.dense_init(ks[2], cfg.vision_dim, cfg.d_model, cfg.params_dtype),
        "supers": supers,
        "final_norm": L.rmsnorm_init(cfg.d_model, cfg.params_dtype),
        "lm_head": {
            "kernel": L.trunc_normal(ks[3], (cfg.d_model, cfg.padded_vocab),
                                     cfg.params_dtype)
        },
    }


def _apply_cross(layer, x, ctx_k, ctx_v, cfg):
    h = L.rmsnorm(layer["xattn_norm"], x, cfg.norm_eps)
    h = _xattn_apply(layer["xattn"], h, ctx_k, ctx_v)
    x = x + jnp.tanh(layer["attn_gate"]).astype(x.dtype) * h
    h = L.rmsnorm(layer["mlp_norm"], x, cfg.norm_eps)
    h = L.mlp(layer["mlp"], h, cfg.act)
    return x + jnp.tanh(layer["mlp_gate"]).astype(x.dtype) * h


def _xattn_apply(params, x, k, v):
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"]["kernel"].astype(x.dtype))
    out = L.flash_attention(q, k, v, causal=False, chunk=min(512, k.shape[1]))
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"]["kernel"].astype(x.dtype))


def backbone(params, x, vision_embeds, cfg, positions):
    from repro.models.transformer import _block

    ctx = L.dense(params["vision_proj"], vision_embeds.astype(cfg.compute_dtype))
    ctx = lshard(ctx, ("batch", "seq", "embed"))

    n_self = cfg.cross_attn_every - 1

    def body(carry, superblock):
        y = carry
        for i in range(n_self):
            y, _ = _block(superblock[str(i)], y, cfg, positions, False)
        ck, cv = W.cross_kv(superblock["cross"]["xattn"], ctx)
        y = _apply_cross(superblock["cross"], y, ck, cv, cfg)
        y = lshard(y, ("batch", "residual_seq", "embed"))
        return y, ()

    body = L.remat_block(body, cfg)
    x, _ = jax.lax.scan(body, x, params["supers"])
    return L.rmsnorm(params["final_norm"], x, cfg.norm_eps), jnp.zeros(())


def forward(params, batch, cfg):
    tokens = batch["tokens"]
    positions = jnp.arange(tokens.shape[1])
    x = jnp.take(params["embed"]["embedding"], tokens, axis=0).astype(cfg.compute_dtype)
    x = lshard(x, ("batch", "seq", "embed"))
    x, aux = backbone(params, x, batch["vision_embeds"], cfg, positions)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"]["kernel"].astype(cfg.compute_dtype))
    return lshard(logits, ("batch", "seq", "vocab")), aux


def loss(params, batch, cfg):
    logits, aux = forward(params, batch, cfg)
    return lm_loss(logits, batch["tokens"], aux, real_vocab=cfg.vocab_size)


# --- serving ----------------------------------------------------------------


def init_decode_state(cfg, batch, max_len, dtype):
    n_super = cfg.num_layers // cfg.cross_attn_every
    n_self = cfg.cross_attn_every - 1
    dh = cfg.head_dim_
    per = {str(i): L.attention_cache_init(cfg, batch, max_len, dtype)
           for i in range(n_self)}
    per["cross_k"] = jnp.zeros((batch, cfg.vision_tokens, cfg.num_kv_heads, dh), dtype)
    per["cross_v"] = jnp.zeros((batch, cfg.vision_tokens, cfg.num_kv_heads, dh), dtype)
    supers = jax.tree.map(lambda a: jnp.broadcast_to(a, (n_super,) + a.shape), per)
    return {"supers": supers, "pos": jnp.zeros((batch,), jnp.int32)}


def prefill_cross(params, state, vision_embeds, cfg):
    ctx = L.dense(params["vision_proj"], vision_embeds.astype(cfg.compute_dtype))

    def body(_, superblock):
        k, v = W.cross_kv(superblock["cross"]["xattn"], ctx)
        return (), (k, v)

    _, (ks, vs) = jax.lax.scan(body, (), params["supers"])
    new = dict(state)
    supers = dict(state["supers"])
    supers["cross_k"], supers["cross_v"] = ks, vs
    new["supers"] = supers
    return new


def decode_step(params, state, tokens, cfg):
    pos = state["pos"]
    x = jnp.take(params["embed"]["embedding"], tokens[:, None], axis=0).astype(cfg.compute_dtype)
    n_self = cfg.cross_attn_every - 1

    # KV caches live in the scan CARRY so the while-loop buffers alias
    # in place (see transformer._decode_scan).
    def body(carry, layer):
        y, supers, j = carry
        st = jax.tree.map(
            lambda c: jax.lax.dynamic_index_in_dim(c, j, 0, keepdims=False), supers
        )
        new_st = dict(st)
        for i in range(n_self):
            li, ci = layer[str(i)], st[str(i)]
            h = L.rmsnorm(li["attn_norm"], y, cfg.norm_eps)
            h, new_st[str(i)] = L.attention_decode(li["attn"], h, ci, pos, cfg)
            y = y + h
            h = L.rmsnorm(li["mlp_norm"], y, cfg.norm_eps)
            y = y + L.mlp(li["mlp"], h, cfg.act)
        cl = layer["cross"]
        h = L.rmsnorm(cl["xattn_norm"], y, cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", h, cl["xattn"]["wq"]["kernel"].astype(h.dtype))
        o = L.cached_attention(cl["xattn"], q, st["cross_k"], st["cross_v"], pos,
                               mask_by_pos=False)
        y = y + jnp.tanh(cl["attn_gate"]).astype(y.dtype) * o
        h = L.rmsnorm(cl["mlp_norm"], y, cfg.norm_eps)
        y = y + jnp.tanh(cl["mlp_gate"]).astype(y.dtype) * L.mlp(cl["mlp"], h, cfg.act)
        supers = jax.tree.map(
            lambda c, nc: jax.lax.dynamic_update_index_in_dim(c, nc, j, 0),
            supers, new_st,
        )
        return (y, supers, j + 1), ()

    (x, new_supers, _), _ = jax.lax.scan(
        body, (x, state["supers"], jnp.zeros((), jnp.int32)), params["supers"]
    )
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"]["kernel"].astype(cfg.compute_dtype))[:, 0]
    return logits, {"supers": new_supers, "pos": pos + 1}


def input_specs(cfg, shape_cfg):
    B, S = shape_cfg.global_batch, shape_cfg.seq_len
    vis = jax.ShapeDtypeStruct((B, cfg.vision_tokens, cfg.vision_dim), cfg.compute_dtype)
    if shape_cfg.kind in ("train", "prefill"):
        return {
            "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
            "vision_embeds": vis,
        }
    return {"tokens": jax.ShapeDtypeStruct((B,), jnp.int32)}
