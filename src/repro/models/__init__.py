"""Architecture zoo: pure-JAX model definitions for the assigned pool."""

from .registry import get_model  # noqa: F401
