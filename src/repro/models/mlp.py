"""The paper's model: a tanh MLP (D -> 768 -> 768 -> 512 -> 512 -> 1) used in
every experiment of section 4, plus the PINN training head.

``loss`` is a Poisson PINN residual  (1/2)|Delta u_theta - rhs|^2 + boundary
term, with the Laplacian computed by the configured operator method (collapsed
Taylor mode by default — the paper's contribution in the training loop).
"""

from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import layers as L


def init(key, cfg) -> Dict[str, Any]:
    sizes = cfg.mlp_sizes
    ks = jax.random.split(key, len(sizes) - 1)
    return {
        f"dense_{i}": {
            "kernel": L.he_normal(k, (a, b), cfg.params_dtype),
            "bias": jnp.zeros((b,), cfg.params_dtype),
        }
        for i, (k, a, b) in enumerate(zip(ks, sizes[:-1], sizes[1:]))
    }


def apply(params, x, cfg):
    """x: (B, D) -> (B,). tanh hidden activations, linear head."""
    n = len(cfg.mlp_sizes) - 1
    h = x
    for i in range(n):
        h = L.dense(params[f"dense_{i}"], h)
        if i < n - 1:
            h = jnp.tanh(h)
    return h[..., 0]


def forward(params, batch, cfg):
    return apply(params, batch["x"], cfg), jnp.zeros(())


# --- PINN objective: -Delta u = rhs on [0,1]^D, u = g on boundary ----------


def manufactured_solution(x):
    """u*(x) = prod_d sin(pi x_d); -Delta u* = D pi^2 u*."""
    return jnp.prod(jnp.sin(math.pi * x), axis=-1)


def rhs(x):
    D = x.shape[-1]
    return D * math.pi**2 * manufactured_solution(x)


def loss(params, batch, cfg, method: str = "collapsed", backend=None):
    from repro.core.operators import laplacian

    x_int, x_bdy = batch["x"], batch.get("x_boundary")
    f = lambda y: apply(params, y, cfg)
    lap = laplacian(f, x_int, method=method, backend=backend)
    residual = -lap - rhs(x_int)
    pde = 0.5 * jnp.mean(residual**2)
    bc = jnp.zeros(())
    if x_bdy is not None:
        bc = 0.5 * jnp.mean((apply(params, x_bdy, cfg) - manufactured_solution(x_bdy)) ** 2)
    total = pde + 10.0 * bc
    return total, {"pde": pde, "bc": bc}


def input_specs(cfg, shape_cfg):
    D = cfg.mlp_sizes[0]
    B = shape_cfg.global_batch * 16  # collocation batches are cheap; widen
    return {
        "x": jax.ShapeDtypeStruct((B, D), jnp.float32),
        "x_boundary": jax.ShapeDtypeStruct((B // 4, D), jnp.float32),
    }


def init_decode_state(cfg, batch, max_len, dtype):  # pragma: no cover - n/a
    raise NotImplementedError("the PINN MLP has no decode path")


decode_step = init_decode_state
