"""xLSTM: mLSTM (matrix memory, chunkwise-parallel) + sLSTM (scalar memory,
scanned) blocks (Beck et al., arXiv:2405.04517).

The 24 blocks of xlstm-350m follow the (m, m, m, s) pattern. ``d_ff = 0``:
there is no separate FFN — the cells carry their own up/down projections.

mLSTM runs in a *chunkwise* form (chunk = 128): intra-chunk attention-like
quadratic over the chunk + inter-chunk recurrent state ``(C, n, m)`` per head,
with running exp-gating stabilizer ``m``. O(S) time/memory: this arch runs the
``long_500k`` cell. Decode carries the same (C, n, m) — no KV cache growth.
"""

from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.distributed.sharding import lshard
from repro.models import layers as L

NEG = -1e30


# ---------------------------------------------------------------------------
# mLSTM cell — chunkwise parallel
# ---------------------------------------------------------------------------


def mlstm_cell_init(key, d_in, num_heads, dtype):
    dh = d_in // num_heads
    ks = jax.random.split(key, 6)
    return {
        "wq": {"kernel": L.trunc_normal(ks[0], (d_in, num_heads, dh), dtype)},
        "wk": {"kernel": L.trunc_normal(ks[1], (d_in, num_heads, dh), dtype)},
        "wv": {"kernel": L.trunc_normal(ks[2], (d_in, num_heads, dh), dtype)},
        "w_igate": L.dense_init(ks[3], d_in, num_heads, dtype, bias=True),
        "w_fgate": L.dense_init(ks[4], d_in, num_heads, dtype, bias=True),
        "out_norm": {"scale": jnp.ones((num_heads, dh), dtype)},
    }


def _mlstm_qkvif(params, x, num_heads):
    dt = jnp.float32
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"]["kernel"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"]["kernel"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"]["kernel"].astype(x.dtype))
    i_log = L.dense(params["w_igate"], x).astype(dt)  # (B,S,H) input gate (log-space)
    f_logsig = jax.nn.log_sigmoid(L.dense(params["w_fgate"], x).astype(dt) + 3.0)
    dh = q.shape[-1]
    q = q / math.sqrt(dh)
    return q, k, v, i_log, f_logsig


def mlstm_chunked(params, x, num_heads, chunk=128):
    """x: (B, S, D) -> (B, S, D). S must be a multiple of chunk (pad if not)."""
    B, S, D = x.shape
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    Sp = x.shape[1]
    n = Sp // chunk
    q, k, v, i_log, f_log = _mlstm_qkvif(params, x, num_heads)
    H, dh = q.shape[2], q.shape[3]

    # reshape to chunks: (n, B, T, H, ...)
    def toc(a):
        return jnp.moveaxis(a.reshape(B, n, chunk, *a.shape[2:]), 1, 0)

    qc, kc, vc, ic, fc = map(toc, (q, k, v, i_log, f_log))

    def step(carry, inp):
        C, nrm, m = carry  # (B,H,dk,dv), (B,H,dk), (B,H)
        qt, kt, vt, it, ft = inp  # (B,T,H,*)
        qt32 = qt.astype(jnp.float32)
        kt32 = kt.astype(jnp.float32)
        b = jnp.cumsum(ft, axis=1)  # (B,T,H) cumulative log-forget within chunk
        btot = b[:, -1]  # (B,H)
        # log weight of step s's kv contribution at end of chunk
        w_end = btot[:, None] - b + it  # (B,T,H)
        m_chunk = jnp.maximum(btot + m, w_end.max(axis=1))  # (B,H)
        # state update
        scale_prev = jnp.exp(btot + m - m_chunk)  # (B,H)
        wk = jnp.exp(w_end - m_chunk[:, None])  # (B,T,H)
        C_new = scale_prev[:, :, None, None] * C + jnp.einsum(
            "bth,bthk,bthv->bhkv", wk, kt32, vt.astype(jnp.float32)
        )
        n_new = scale_prev[:, :, None] * nrm + jnp.einsum("bth,bthk->bhk", wk, kt32)
        # outputs within chunk: inter (from C) + intra (masked quadratic)
        w_q = b + m[:, None, :]  # (B,T,H) log weight of C_prev contribution
        s_intra = b[:, :, None, :] - b[:, None, :, :] + it[:, None, :, :]  # (B,T,S,H)
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        s_intra = jnp.where(tri[None, :, :, None], s_intra, NEG)
        m_row = jnp.maximum(w_q, s_intra.max(axis=2))  # (B,T,H)
        d_intra = jnp.exp(s_intra - m_row[:, :, None, :])  # (B,T,S,H)
        qk = jnp.einsum("bthk,bshk->btsh", qt32, kt32)
        h_intra = jnp.einsum("btsh,btsh,bshv->bthv", qk, d_intra, vt.astype(jnp.float32))
        h_inter = jnp.exp(w_q - m_row)[..., None] * jnp.einsum(
            "bthk,bhkv->bthv", qt32, C
        )
        qn_intra = jnp.einsum("btsh,btsh->bth", qk, d_intra)
        qn_inter = jnp.exp(w_q - m_row) * jnp.einsum("bthk,bhk->bth", qt32, nrm)
        denom = jnp.maximum(jnp.abs(qn_intra + qn_inter), jnp.exp(-m_row))
        h = (h_intra + h_inter) / denom[..., None]
        return (C_new, n_new, m_chunk), h.astype(x.dtype)

    C0 = jnp.zeros((B, H, dh, dh), jnp.float32)
    n0 = jnp.zeros((B, H, dh), jnp.float32)
    m0 = jnp.zeros((B, H), jnp.float32)
    (_, _, _), hs = jax.lax.scan(step, (C0, n0, m0), (qc, kc, vc, ic, fc))
    h = jnp.moveaxis(hs, 0, 1).reshape(B, Sp, H, dh)
    h = h * _headnorm(params["out_norm"], h)
    h = h.reshape(B, Sp, D)
    return h[:, :S] if pad else h


def _headnorm(p, h):
    # per-head RMS normalization of outputs (xLSTM GroupNorm analogue)
    var = jnp.mean(h.astype(jnp.float32) ** 2, axis=-1, keepdims=True)
    return (jax.lax.rsqrt(var + 1e-6) * p["scale"].astype(jnp.float32)).astype(h.dtype)


def mlstm_step(params, x, state, num_heads):
    """Decode step. x: (B, 1, D); state: (C, n, m)."""
    q, k, v, i_log, f_log = _mlstm_qkvif(params, x, num_heads)
    C, nrm, m = state
    qt = q[:, 0].astype(jnp.float32)  # (B,H,dk)
    kt = k[:, 0].astype(jnp.float32)
    vt = v[:, 0].astype(jnp.float32)
    it, ft = i_log[:, 0], f_log[:, 0]  # (B,H)
    m_new = jnp.maximum(ft + m, it)
    fs = jnp.exp(ft + m - m_new)
    is_ = jnp.exp(it - m_new)
    C_new = fs[:, :, None, None] * C + is_[:, :, None, None] * (
        kt[:, :, :, None] * vt[:, :, None, :]
    )
    n_new = fs[:, :, None] * nrm + is_[:, :, None] * kt
    num = jnp.einsum("bhk,bhkv->bhv", qt, C_new)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", qt, n_new)), jnp.exp(-m_new))
    h = (num / den[..., None])[:, None]  # (B,1,H,dv)
    h = h * _headnorm(params["out_norm"], h)
    B, _, H, dh = h.shape
    return h.reshape(B, 1, H * dh).astype(x.dtype), (C_new, n_new, m_new)


# ---------------------------------------------------------------------------
# sLSTM cell — scanned scalar memory with block-diagonal recurrence
# ---------------------------------------------------------------------------


def slstm_cell_init(key, d, num_heads, dtype):
    dh = d // num_heads
    ks = jax.random.split(key, 8)
    gates = {}
    for name, kk in zip(("z", "i", "f", "o"), ks[:4]):
        gates[f"w_{name}"] = L.dense_init(kk, d, d, dtype, bias=True)
    for name, kk in zip(("z", "i", "f", "o"), ks[4:]):
        gates[f"r_{name}"] = L.trunc_normal(kk, (num_heads, dh, dh), dtype,
                                            std=1.0 / math.sqrt(dh))
    gates["out_norm"] = {"scale": jnp.ones((num_heads, dh), dtype)}
    return gates


def slstm(params, x, num_heads, state=None):
    """x: (B, S, D) -> (B, S, D); lax.scan over time."""
    B, S, D = x.shape
    dh = D // num_heads
    wx = {
        g: L.dense(params[f"w_{g}"], x).astype(jnp.float32)
        for g in ("z", "i", "f", "o")
    }  # each (B,S,D)

    def rmat(g, h):  # block-diagonal recurrent matmul
        hh = h.reshape(B, num_heads, dh)
        return jnp.einsum("bhk,hkj->bhj", hh, params[f"r_{g}"].astype(jnp.float32)).reshape(B, D)

    def step(carry, inp):
        c, n, m, h = carry
        xz, xi, xf, xo = inp
        z = jnp.tanh(xz + rmat("z", h))
        it = xi + rmat("i", h)
        ft = jax.nn.log_sigmoid(xf + rmat("f", h) + 3.0)
        o = jax.nn.sigmoid(xo + rmat("o", h))
        m_new = jnp.maximum(ft + m, it)
        i_ = jnp.exp(it - m_new)
        f_ = jnp.exp(ft + m - m_new)
        c_new = f_ * c + i_ * z
        n_new = jnp.maximum(f_ * n + i_, 1.0)
        h_new = o * (c_new / n_new)
        return (c_new, n_new, m_new, h_new), h_new

    if state is None:
        z0 = jnp.zeros((B, D), jnp.float32)
        state = (z0, jnp.ones((B, D), jnp.float32), z0, z0)
    xs = tuple(jnp.moveaxis(wx[g], 1, 0) for g in ("z", "i", "f", "o"))
    state_out, hs = jax.lax.scan(step, state, xs)
    h = jnp.moveaxis(hs, 0, 1)  # (B,S,D)
    hh = h.reshape(B, S, num_heads, dh)
    hh = hh * _headnorm(params["out_norm"], hh)
    return hh.reshape(B, S, D).astype(x.dtype), state_out


# ---------------------------------------------------------------------------
# blocks & model
# ---------------------------------------------------------------------------


def _mblock_init(key, cfg):
    from repro.models.recurrentgemma import conv1d_init

    d = cfg.d_model
    up = 2 * d
    ks = jax.random.split(key, 5)
    return {
        "norm": L.rmsnorm_init(d, cfg.params_dtype),
        "up": L.dense_init(ks[0], d, 2 * up, cfg.params_dtype),
        "conv": conv1d_init(ks[1], up, 4, cfg.params_dtype),
        "cell": mlstm_cell_init(ks[2], up, cfg.num_heads, cfg.params_dtype),
        "down": L.dense_init(ks[3], up, d, cfg.params_dtype),
    }


def _sblock_init(key, cfg):
    d = cfg.d_model
    ks = jax.random.split(key, 2)
    return {
        "norm": L.rmsnorm_init(d, cfg.params_dtype),
        "cell": slstm_cell_init(ks[0], d, cfg.num_heads, cfg.params_dtype),
        "proj": L.dense_init(ks[1], d, d, cfg.params_dtype),
    }


def _mblock(params, x, cfg, chunk=128):
    from repro.models.recurrentgemma import causal_conv1d

    h = L.rmsnorm(params["norm"], x, cfg.norm_eps)
    u = L.dense(params["up"], h)
    a, gate = jnp.split(u, 2, axis=-1)
    a = jax.nn.silu(causal_conv1d(params["conv"], a))
    a = mlstm_chunked(params["cell"], a, cfg.num_heads, chunk=chunk)
    a = a * jax.nn.silu(gate)
    return x + L.dense(params["down"], a)


def _sblock(params, x, cfg):
    h = L.rmsnorm(params["norm"], x, cfg.norm_eps)
    h, _ = slstm(params["cell"], h, cfg.num_heads)
    return x + L.dense(params["proj"], h)


def init(key, cfg) -> Dict[str, Any]:
    pat = cfg.block_pattern or ("m", "m", "m", "s")
    n_super, n_rem = divmod(cfg.num_layers, len(pat))
    assert n_rem == 0, "xlstm layer count must tile the block pattern"
    keys = jax.random.split(key, 3)

    def one_super(k):
        ks = jax.random.split(k, len(pat))
        return {
            str(i): (_mblock_init(kk, cfg) if kind == "m" else _sblock_init(kk, cfg))
            for i, (kk, kind) in enumerate(zip(ks, pat))
        }

    supers = jax.vmap(one_super)(jax.random.split(keys[0], n_super))
    return {
        "embed": {
            "embedding": L.trunc_normal(keys[1], (cfg.padded_vocab, cfg.d_model),
                                        cfg.params_dtype)
        },
        "supers": supers,
        "final_norm": L.rmsnorm_init(cfg.d_model, cfg.params_dtype),
        "lm_head": {
            "kernel": L.trunc_normal(keys[2], (cfg.d_model, cfg.padded_vocab),
                                     cfg.params_dtype)
        },
    }


def backbone(params, x, cfg, positions=None):
    pat = cfg.block_pattern or ("m", "m", "m", "s")

    def body(carry, superblock):
        y = carry
        for i, kind in enumerate(pat):
            y = _mblock(superblock[str(i)], y, cfg) if kind == "m" else _sblock(
                superblock[str(i)], y, cfg
            )
            y = lshard(y, ("batch", "seq", "embed"))
        return y, ()

    body = L.remat_block(body, cfg)
    x, _ = jax.lax.scan(body, x, params["supers"])
    return L.rmsnorm(params["final_norm"], x, cfg.norm_eps), jnp.zeros(())


def forward(params, batch, cfg):
    tokens = batch["tokens"]
    x = jnp.take(params["embed"]["embedding"], tokens, axis=0).astype(cfg.compute_dtype)
    x = lshard(x, ("batch", "seq", "embed"))
    x, aux = backbone(params, x, cfg, None)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"]["kernel"].astype(cfg.compute_dtype))
    return lshard(logits, ("batch", "seq", "vocab")), aux


def loss(params, batch, cfg):
    from repro.models.transformer import lm_loss

    logits, aux = forward(params, batch, cfg)
    return lm_loss(logits, batch["tokens"], aux, real_vocab=cfg.vocab_size)


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def init_decode_state(cfg, batch, max_len, dtype):
    pat = cfg.block_pattern or ("m", "m", "m", "s")
    n_super = cfg.num_layers // len(pat)
    d = cfg.d_model
    H = cfg.num_heads
    per = {}
    for i, kind in enumerate(pat):
        if kind == "m":
            up = 2 * d
            dh = up // H
            per[str(i)] = {
                "conv": jnp.zeros((batch, 3, up), dtype),
                "C": jnp.zeros((batch, H, dh, dh), jnp.float32),
                "n": jnp.zeros((batch, H, dh), jnp.float32),
                "m": jnp.zeros((batch, H), jnp.float32),
            }
        else:
            z = jnp.zeros((batch, d), jnp.float32)
            per[str(i)] = {"c": z, "n": jnp.ones_like(z), "m": z, "h": z}
    supers = jax.tree.map(lambda a: jnp.broadcast_to(a, (n_super,) + a.shape), per)
    return {"supers": supers, "pos": jnp.zeros((batch,), jnp.int32)}


def decode_step(params, state, tokens, cfg):
    from repro.models.recurrentgemma import causal_conv1d_step

    pat = cfg.block_pattern or ("m", "m", "m", "s")
    x = jnp.take(params["embed"]["embedding"], tokens[:, None], axis=0).astype(cfg.compute_dtype)

    def body(carry, layer_and_state):
        y = carry
        layer, st = layer_and_state
        new_st = {}
        for i, kind in enumerate(pat):
            li, si = layer[str(i)], st[str(i)]
            if kind == "m":
                h = L.rmsnorm(li["norm"], y, cfg.norm_eps)
                u = L.dense(li["up"], h)
                a, gate = jnp.split(u, 2, axis=-1)
                a, conv_w = causal_conv1d_step(li["conv"], a, si["conv"])
                a = jax.nn.silu(a)
                a, (C, n, m) = mlstm_step(li["cell"], a, (si["C"], si["n"], si["m"]),
                                          cfg.num_heads)
                a = a * jax.nn.silu(gate)
                y = y + L.dense(li["down"], a)
                new_st[str(i)] = {"conv": conv_w, "C": C, "n": n, "m": m}
            else:
                h = L.rmsnorm(li["norm"], y, cfg.norm_eps)
                hseq, st_out = slstm(li["cell"], h, cfg.num_heads,
                                     state=(si["c"], si["n"], si["m"], si["h"]))
                y = y + L.dense(li["proj"], hseq)
                c, n, m, hh = st_out
                new_st[str(i)] = {"c": c, "n": n, "m": m, "h": hh}
        return y, new_st

    x, new_supers = jax.lax.scan(body, x, (params["supers"], state["supers"]))
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"]["kernel"].astype(cfg.compute_dtype))[:, 0]
    return logits, {"supers": new_supers, "pos": state["pos"] + 1}


def input_specs(cfg, shape_cfg):
    B, S = shape_cfg.global_batch, shape_cfg.seq_len
    if shape_cfg.kind in ("train", "prefill"):
        return {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    return {"tokens": jax.ShapeDtypeStruct((B,), jnp.int32)}
