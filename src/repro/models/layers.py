"""Shared layers: norms, dense, RoPE, streaming-softmax attention (flash-style
with a custom VJP so both directions are O(seq) memory in pure JAX), SwiGLU
MLP, and capacity-based sort-dispatch MoE.

Everything is functional: params are nested dicts, layers are plain functions.
Activation sharding uses logical-axis annotations (distributed.sharding).
"""

from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import lshard


def remat_block(body, cfg):
    """Wrap a scan body with the configured activation-checkpoint policy.

    'nothing' = full remat (only layer-boundary carries survive — the memory
    floor; backward recompute cost is visible in the jaxpr cost model);
    'dots' = save matmul outputs (less recompute, ~10x more activation HBM).
    The choice is a section-Perf hillclimb lever.
    """
    if not cfg.remat:
        return body
    pol = {
        "nothing": jax.checkpoint_policies.nothing_saveable,
        "dots": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
    }[cfg.remat_policy]
    return jax.checkpoint(body, policy=pol)


def he_normal(key, shape, dtype, fan_in=None):
    fan_in = fan_in or shape[0]
    return (jax.random.normal(key, shape) * (1.0 / math.sqrt(fan_in))).astype(dtype)


def trunc_normal(key, shape, dtype, std=0.02):
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape) * std).astype(dtype)


# ---------------------------------------------------------------------------
# norms / dense
# ---------------------------------------------------------------------------


def rmsnorm_init(d, dtype):
    return {"scale": jnp.ones((d,), dtype=dtype)}


def rmsnorm(params, x, eps=1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return out.astype(dt) * params["scale"].astype(dt)


def layernorm_init(d, dtype):
    return {"scale": jnp.ones((d,), dtype=dtype), "bias": jnp.zeros((d,), dtype=dtype)}


def layernorm(params, x, eps=1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return out.astype(dt) * params["scale"].astype(dt) + params["bias"].astype(dt)


def dense_init(key, d_in, d_out, dtype, bias=False, std=None):
    p = {"kernel": trunc_normal(key, (d_in, d_out), dtype, std or 0.02)}
    if bias:
        p["bias"] = jnp.zeros((d_out,), dtype=dtype)
    return p


def dense(params, x, dtype=None):
    k = params["kernel"]
    if dtype is not None:
        k = k.astype(dtype)
        x = x.astype(dtype)
    y = x @ k
    if "bias" in params:
        y = y + params["bias"].astype(y.dtype)
    return y


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------


def rope(x, positions, theta=10_000.0):
    """x: (..., S, H, dh), positions: (S,) or (..., S)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = jnp.arange(half, dtype=jnp.float32)
    inv = theta ** (-freqs / half)
    ang = positions.astype(jnp.float32)[..., :, None] * inv  # (..., S, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[..., :, None, :].astype(x.dtype)  # broadcast over heads
    sin = sin[..., :, None, :].astype(x.dtype)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


# ---------------------------------------------------------------------------
# streaming-softmax attention (flash-style) with custom VJP
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def _chunk_mask(q_pos, k_pos, causal, window, kv_len):
    """(Sq, Cb) boolean allowed-mask."""
    m = k_pos[None, :] < kv_len
    if causal:
        m = m & (k_pos[None, :] <= q_pos[:, None])
    if window is not None:
        m = m & (q_pos[:, None] - k_pos[None, :] < window)
    return m


def masked_softmax(s, mask=None):
    """Row softmax over the trailing (key) axis with an optional boolean mask
    (True = attend).

    This is the ONE canonical mask/softmax subgraph: every non-streaming
    attention path (attention_reference — which kernels/flash_attention/ref.py
    re-exports as its oracle — and the decode-time cached_attention) traces
    through it, so the offload probe classifier
    (:mod:`repro.core.offload`) sees a single graph shape:
    ``where(mask, s, -1e30) -> stop_gradient'd row max -> exp -> row sum ->
    div``. The max shift is stop_gradient'd so Taylor/jet interpreters treat
    it as the constant it mathematically is.
    """
    if mask is not None:
        s = jnp.where(mask, s, NEG_INF)
    m = jax.lax.stop_gradient(jnp.max(s, axis=-1, keepdims=True))
    e = jnp.exp(s - m)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def _flash_fwd_impl(q, k, v, causal, window, q_offset, chunk, kv_len):
    """q: (B, Sq, Hkv, G, dh); k, v: (B, Skv, Hkv, dh).

    Returns out (B, Sq, Hkv, G, dh) and logsumexp L (B, Hkv, G, Sq).
    """
    B, Sq, Hkv, G, dh = q.shape
    Skv = k.shape[1]
    nchunk = max(Skv // chunk, 1)
    chunk = Skv // nchunk
    scale = 1.0 / math.sqrt(dh)
    q_pos = q_offset + jnp.arange(Sq)

    kc = k.reshape(B, nchunk, chunk, Hkv, dh)
    vc = v.reshape(B, nchunk, chunk, Hkv, dh)
    kc = jnp.moveaxis(kc, 1, 0)  # (n, B, chunk, Hkv, dh)
    vc = jnp.moveaxis(vc, 1, 0)

    def step(carry, inp):
        acc, m, l = carry
        kch, vch, c0 = inp
        k_pos = c0 + jnp.arange(chunk)
        s = jnp.einsum("bqhgd,bchd->bhgqc", q, kch,
                       preferred_element_type=jnp.float32) * scale
        mask = _chunk_mask(q_pos, k_pos, causal, window, kv_len)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bhgqc,bchd->bhgqd", p.astype(vch.dtype), vch,
                        preferred_element_type=jnp.float32)
        acc_new = acc * corr[..., None] + pv
        return (acc_new, m_new, l_new), None

    acc0 = jnp.zeros((B, Hkv, G, Sq, dh), jnp.float32)
    m0 = jnp.full((B, Hkv, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, Sq), jnp.float32)
    c0s = jnp.arange(nchunk) * chunk
    (acc, m, l), _ = jax.lax.scan(step, (acc0, m0, l0), (kc, vc, c0s))
    l = jnp.maximum(l, 1e-30)
    out = (acc / l[..., None]).astype(q.dtype)
    L = m + jnp.log(l)
    return jnp.moveaxis(out, 3, 1), L  # (B, Sq, Hkv, G, dh), (B,Hkv,G,Sq)


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _flash(q, k, v, causal, window, q_offset, chunk, kv_len):
    out, _ = _flash_fwd_impl(q, k, v, causal, window, q_offset, chunk, kv_len)
    return out


def _flash_fwd(q, k, v, causal, window, q_offset, chunk, kv_len):
    out, L = _flash_fwd_impl(q, k, v, causal, window, q_offset, chunk, kv_len)
    return out, (q, k, v, out, L)


def _flash_bwd(causal, window, q_offset, chunk, kv_len, res, dout):
    q, k, v, out, L = res
    B, Sq, Hkv, G, dh = q.shape
    Skv = k.shape[1]
    nchunk = max(Skv // chunk, 1)
    chunk_ = Skv // nchunk
    scale = 1.0 / math.sqrt(dh)
    q_pos = q_offset + jnp.arange(Sq)

    do = jnp.moveaxis(dout, 1, 3)  # (B, Hkv, G, Sq, dh)
    o = jnp.moveaxis(out, 1, 3)
    D = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1)  # (B,Hkv,G,Sq)

    kc = jnp.moveaxis(k.reshape(B, nchunk, chunk_, Hkv, dh), 1, 0)
    vc = jnp.moveaxis(v.reshape(B, nchunk, chunk_, Hkv, dh), 1, 0)
    c0s = jnp.arange(nchunk) * chunk_

    def step(dq_acc, inp):
        kch, vch, c0 = inp
        k_pos = c0 + jnp.arange(chunk_)
        s = jnp.einsum("bqhgd,bchd->bhgqc", q, kch,
                       preferred_element_type=jnp.float32) * scale
        mask = _chunk_mask(q_pos, k_pos, causal, window, kv_len)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        p = jnp.exp(s - L[..., None])  # (B,Hkv,G,Sq,c)
        dv = jnp.einsum("bhgqc,bhgqd->bchd", p, do.astype(jnp.float32))
        dp = jnp.einsum("bhgqd,bchd->bhgqc", do.astype(jnp.float32),
                        vch.astype(jnp.float32))
        ds = p * (dp - D[..., None]) * scale
        dq_c = jnp.einsum("bhgqc,bchd->bqhgd", ds, kch.astype(jnp.float32))
        dk = jnp.einsum("bhgqc,bqhgd->bchd", ds, q.astype(jnp.float32))
        return dq_acc + dq_c, (dk, dv)

    dq0 = jnp.zeros(q.shape, jnp.float32)
    dq, (dks, dvs) = jax.lax.scan(step, dq0, (kc, vc, c0s))
    dk = jnp.moveaxis(dks, 0, 1).reshape(B, Skv, Hkv, dh)
    dv = jnp.moveaxis(dvs, 0, 1).reshape(B, Skv, Hkv, dh)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(
    q, k, v, *, causal=True, window=None, q_offset=0, chunk=512, kv_len=None
):
    """Grouped-query streaming attention.

    q: (B, Sq, Hq, dh); k, v: (B, Skv, Hkv, dh); Hq = Hkv * G.
    O(Skv/chunk) working set in fwd and bwd; numerically = softmax attention.
    """
    B, Sq, Hq, dh = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, G, dh)
    if kv_len is None:
        kv_len = k.shape[1]
    chunk = min(chunk, k.shape[1])
    pad = (-k.shape[1]) % chunk
    if pad:  # pad KV to a chunk multiple; padded columns masked via kv_len
        widths = ((0, 0), (0, pad), (0, 0), (0, 0))
        k = jnp.pad(k, widths)
        v = jnp.pad(v, widths)
    out = _flash(qg, k, v, causal, window, q_offset, chunk, kv_len)
    return out.reshape(B, Sq, Hq, dh)


def attention_reference(q, k, v, *, causal=True, window=None, q_offset=0, kv_len=None):
    """Naive softmax attention (oracle for flash & the Pallas kernels).

    Canonical graph: GQA key/value heads are broadcast over their query
    groups, everything is laid out ``(B, H, S, dh)``, and the block is two
    batched dot_generals around the shared :func:`masked_softmax` — the
    attention shape :mod:`repro.core.offload`'s jet_attention matcher fuses
    when this runs under a collapsed-Taylor operator with
    ``backend='pallas'``.
    """
    B, Sq, Hq, dh = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    qh = jnp.moveaxis(q, 2, 1)  # (B, Hq, Sq, dh)
    kh = jnp.moveaxis(k, 2, 1)
    vh = jnp.moveaxis(v, 2, 1)
    s = jnp.einsum("bhqd,bhkd->bhqk", qh, kh,
                   preferred_element_type=jnp.float32) / math.sqrt(dh)
    q_pos = q_offset + jnp.arange(Sq)
    k_pos = jnp.arange(Skv)
    mask = _chunk_mask(q_pos, k_pos, causal, window,
                       kv_len if kv_len is not None else Skv)
    p = masked_softmax(s, mask)
    o = jnp.einsum("bhqk,bhkd->bhqd", p.astype(vh.dtype), vh,
                   preferred_element_type=jnp.float32)
    return jnp.moveaxis(o, 1, 2).astype(q.dtype)


# ---------------------------------------------------------------------------
# attention layer (projections + cache handling)
# ---------------------------------------------------------------------------


def attention_init(key, cfg, d_model=None, dtype=None):
    d = d_model or cfg.d_model
    dh = cfg.head_dim_
    dt = dtype or cfg.params_dtype
    ks = jax.random.split(key, 4)
    p = {
        "wq": {"kernel": trunc_normal(ks[0], (d, cfg.num_heads, dh), dt)},
        "wk": {"kernel": trunc_normal(ks[1], (d, cfg.num_kv_heads, dh), dt)},
        "wv": {"kernel": trunc_normal(ks[2], (d, cfg.num_kv_heads, dh), dt)},
        "wo": {"kernel": trunc_normal(ks[3], (cfg.num_heads, dh, d), dt)},
    }
    if cfg.qkv_bias:
        p["wq"]["bias"] = jnp.zeros((cfg.num_heads, dh), dt)
        p["wk"]["bias"] = jnp.zeros((cfg.num_kv_heads, dh), dt)
        p["wv"]["bias"] = jnp.zeros((cfg.num_kv_heads, dh), dt)
    return p


def _proj_qkv(params, x, cfg):
    dt = x.dtype

    def pj(p, name):
        y = jnp.einsum("bsd,dhk->bshk", x, p["kernel"].astype(dt))
        if "bias" in p:
            y = y + p["bias"].astype(dt)
        return y

    q = pj(params["wq"], "q")
    k = pj(params["wk"], "k")
    v = pj(params["wv"], "v")
    q = lshard(q, ("batch", "seq", "heads", None))
    k = lshard(k, ("batch", "seq", "kv_heads", None))
    v = lshard(v, ("batch", "seq", "kv_heads", None))
    return q, k, v


def attention_layer(params, x, cfg, *, positions, causal=True, window=None):
    """Training/prefill path: full-sequence streaming attention.

    ``cfg.attn_impl='reference'`` swaps in the canonical
    :func:`attention_reference` graph — the form the collapsed-Taylor
    offload planner fuses; differential-operator heads (transformer PINNs)
    trace with that setting. The recursive offload engine plans through
    ``lax.scan``, so this fuses both in unrolled trunks and inside the
    scanned layer stack of ``models/transformer.backbone``. The planner
    fuses projections + GQA attention + output projection as ONE
    superblock kernel in both conventions: ``cfg.use_rope=False`` (PINN —
    coordinates carry their own positional lift, q/k/v feed the score dot
    directly) and the LM default ``cfg.use_rope=True`` (+
    ``cfg.qkv_bias``) — the jet-constant rotary tables and projection
    biases fold into the kernel's projection stage (rope is linear per
    position, so every Taylor coefficient rotates identically)."""
    q, k, v = _proj_qkv(params, x, cfg)
    if getattr(cfg, "use_rope", True):
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    if getattr(cfg, "attn_impl", "flash") == "reference":
        out = attention_reference(q, k, v, causal=causal, window=window)
    elif cfg.use_pallas:
        from repro.kernels.flash_attention import ops as fa_ops

        out = fa_ops.flash_attention(q, k, v, causal=causal, window=window)
    else:
        out = flash_attention(q, k, v, causal=causal, window=window, chunk=cfg.attn_chunk)
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"]["kernel"].astype(x.dtype))
    return lshard(out, ("batch", "seq", "embed"))


def cache_insert(cache_kv, kv, pos):
    """Per-slot cache write: cache (B, Smax, H, dh), kv (B, 1, H, dh),
    pos (B,) int32 — slot b writes at its own position (continuous batching)."""
    return jax.vmap(
        lambda c, u, p: jax.lax.dynamic_update_slice(c, u, (p, 0, 0))
    )(cache_kv, kv.astype(cache_kv.dtype), pos)


def attention_decode(params, x, cache, pos, cfg, *, window=None, use_rope=True):
    """Single-token decode with a static-size KV cache.

    x: (B, 1, D); cache: {'k','v': (B, Smax, Hkv, dh)}; pos: (B,) int32
    (per-slot positions). Returns (out, new_cache).
    """
    q, k, v = _proj_qkv(params, x, cfg)
    if use_rope and getattr(cfg, "use_rope", True):
        q = rope(q, pos[:, None], cfg.rope_theta)
        k = rope(k, pos[:, None], cfg.rope_theta)
    ck = cache_insert(cache["k"], k, pos)
    cv = cache_insert(cache["v"], v, pos)
    out = cached_attention(params, q, ck, cv, pos, window=window)
    return out, {"k": ck, "v": cv}


def cached_attention(params, q, ck, cv, pos, *, window=None, mask_by_pos=True):
    """Attention of a 1-token query against a (possibly padded) cache.
    pos: (B,) per-slot positions (ignored when mask_by_pos=False)."""
    B, _, Hq, dh = q.shape
    Hkv = ck.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, 1, Hkv, G, dh)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, ck, preferred_element_type=jnp.float32)
    s = s / math.sqrt(dh)
    k_pos = jnp.arange(ck.shape[1])
    if mask_by_pos:
        ok = k_pos[None] <= pos[:, None]  # (B, S)
        if window is not None:
            ok = ok & (pos[:, None] - k_pos[None] < window)
        p = masked_softmax(s, ok[:, None, None, None, :])
    else:
        p = masked_softmax(s)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(cv.dtype), cv)
    o = o.reshape(B, 1, Hq, dh)
    return jnp.einsum("bshk,hkd->bsd", o, params["wo"]["kernel"].astype(q.dtype))


def attention_cache_init(cfg, batch, max_len, dtype):
    dh = cfg.head_dim_
    return {
        "k": jnp.zeros((batch, max_len, cfg.num_kv_heads, dh), dtype),
        "v": jnp.zeros((batch, max_len, cfg.num_kv_heads, dh), dtype),
    }


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def mlp_init(key, d, d_ff, dtype, act="silu"):
    ks = jax.random.split(key, 3)
    p = {
        "w_in": dense_init(ks[0], d, d_ff, dtype),
        "w_out": dense_init(ks[1], d_ff, d, dtype),
    }
    if act == "silu":  # gated (SwiGLU)
        p["w_gate"] = dense_init(ks[2], d, d_ff, dtype)
    return p


def mlp(params, x, act="silu"):
    h = dense(params["w_in"], x)
    if act == "silu":
        h = jax.nn.silu(dense(params["w_gate"], x)) * h
    elif act == "gelu":
        h = jax.nn.gelu(h)
    elif act == "tanh":
        h = jnp.tanh(h)
    else:
        raise ValueError(act)
    h = lshard(h, ("batch", "seq", "mlp"))
    return dense(params["w_out"], h)


# ---------------------------------------------------------------------------
# Mixture of Experts: top-k routing, sort-based capacity dispatch
# ---------------------------------------------------------------------------


def moe_init(key, cfg, dtype):
    E, d, f = cfg.num_experts, cfg.d_model, cfg.moe_d_ff or cfg.d_ff
    ks = jax.random.split(key, 6)
    p = {
        "router": dense_init(ks[0], d, E, dtype),
        "experts": {
            "w_in": trunc_normal(ks[1], (E, d, f), dtype),
            "w_gate": trunc_normal(ks[2], (E, d, f), dtype),
            "w_out": trunc_normal(ks[3], (E, f, d), dtype),
        },
    }
    if cfg.num_shared_experts:
        p["shared"] = mlp_init(ks[4], d, f * cfg.num_shared_experts, dtype)
    if cfg.dense_residual_d_ff:
        p["dense_residual"] = mlp_init(ks[5], d, cfg.dense_residual_d_ff, dtype)
    return p


def moe(params, x, cfg):
    """x: (B, S, D) -> (out, aux_loss).

    Sort-based dispatch with static capacity (MegaBlocks-style grouping
    adapted to static TPU shapes). Routing/sorting/scatter are performed
    *per sequence* (independently along the batch axis) so the whole dispatch
    pipeline shards over the data axes with zero cross-shard traffic; only the
    expert einsums touch the expert-parallel axis. Capacity is per-sequence
    (Switch-style per-shard capacity).
    """
    B, S, D = x.shape
    chunk = cfg.moe_seq_chunk
    if chunk and S > chunk and S % chunk == 0:
        # long sequences: scan over sequence chunks so dispatch intermediates
        # (gathered tokens, expert buffers) are transient per chunk. Capacity
        # becomes per-chunk (Switch-style local capacity).
        nc = S // chunk
        xc = jnp.moveaxis(x.reshape(B, nc, chunk, D), 1, 0)

        def body(aux_acc, xi):
            out_i, aux_i = moe(params, xi, cfg)
            return aux_acc + aux_i, out_i

        aux, outs = jax.lax.scan(body, jnp.zeros(()), xc)
        return jnp.moveaxis(outs, 0, 1).reshape(B, S, D), aux / nc

    E, k = cfg.num_experts, cfg.experts_per_token
    Nk = S * k
    C = int(math.ceil(S * k / E * cfg.capacity_factor))
    C = max(8, -(-C // 8) * 8)  # round up to a multiple of 8

    logits = dense(params["router"], x.astype(jnp.float32))  # (B, S, E) fp32
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)  # (B, S, k)
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)

    # --- load-balance auxiliary loss (Switch) ---
    me = probs.mean(axis=(0, 1))  # (E,)
    onehot_e = jax.nn.one_hot(expert_idx, E, dtype=jnp.float32)  # (B,S,k,E)
    ce = onehot_e.mean(axis=(0, 1, 2))
    aux = E * jnp.sum(me * ce)

    # --- per-sequence sort-based dispatch (vectorized over B) ---
    flat_e = expert_idx.reshape(B, Nk)
    order = jnp.argsort(flat_e, axis=1)  # (B, Nk) stable group-by-expert
    sorted_e = jnp.take_along_axis(flat_e, order, axis=1)
    idx = jnp.arange(Nk)[None]
    is_start = jnp.concatenate(
        [jnp.ones((B, 1), bool), sorted_e[:, 1:] != sorted_e[:, :-1]], axis=1
    )
    seg_start = jax.lax.cummax(jnp.where(is_start, idx, 0), axis=1)
    pos_in_e = idx - seg_start
    valid = pos_in_e < C
    dest = jnp.where(valid, sorted_e * C + pos_in_e, E * C)  # (B, Nk)
    tok = order // k  # source token within the sequence

    xin = jnp.take_along_axis(
        x, tok[..., None], axis=1
    )  # (B, Nk, D) gather within sequence
    xin = lshard(xin, ("batch", None, "embed"))
    scatter_row = lambda xi, de, va: jnp.zeros((E * C + 1, D), x.dtype).at[de].add(
        jnp.where(va[:, None], xi, 0)
    )[: E * C]
    buf = jax.vmap(scatter_row)(xin, dest, valid)  # (B, E*C, D)
    buf = buf.reshape(B, E, C, D)
    buf = lshard(buf, ("batch", "experts", "expert_capacity", "embed"))

    we = params["experts"]
    h = jnp.einsum("becd,edf->becf", buf, we["w_in"].astype(x.dtype))
    g = jnp.einsum("becd,edf->becf", buf, we["w_gate"].astype(x.dtype))
    h = jax.nn.silu(g) * h
    h = lshard(h, ("batch", "experts", "expert_capacity", "expert_mlp"))
    eo = jnp.einsum("becf,efd->becd", h, we["w_out"].astype(x.dtype))

    eo_flat = jnp.concatenate(
        [eo.reshape(B, E * C, D), jnp.zeros((B, 1, D), eo.dtype)], axis=1
    )
    back = jnp.take_along_axis(eo_flat, dest[..., None], axis=1)  # (B, Nk, D)
    back = lshard(back, ("batch", None, "embed"))
    gate_sorted = jnp.take_along_axis(gate_vals.reshape(B, Nk), order, axis=1)
    contrib = back * (gate_sorted * valid)[..., None].astype(back.dtype)
    out = jax.vmap(
        lambda co, to: jnp.zeros((S, D), x.dtype).at[to].add(co)
    )(contrib, tok)
    out = lshard(out, ("batch", None, "embed"))

    if "shared" in params:
        out = out + mlp(params["shared"], x, act="silu")
    if "dense_residual" in params:
        out = out + mlp(params["dense_residual"], x, act="silu")
    return out, aux
