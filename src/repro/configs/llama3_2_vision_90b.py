"""llama-3.2-vision-90b [vlm]: cross-attn image layers every 5th layer
(hf:meta-llama/Llama-3.2-90B-Vision). Vision tower STUBBED: input_specs
provides patch embeddings (B, 6404, 1280).

100L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-vision-90b", family="vlm",
    num_layers=100, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=28672, vocab_size=128_256, rope_theta=500_000.0,
    cross_attn_every=5, vision_tokens=6404, vision_dim=1280,
)

SMOKE = CONFIG.replace(
    num_layers=4, d_model=32, num_heads=4, num_kv_heads=2, head_dim=8,
    d_ff=64, vocab_size=199, cross_attn_every=2, vision_tokens=9,
    vision_dim=16, dtype="float32", attn_chunk=8,
)
