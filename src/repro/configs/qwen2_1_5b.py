"""qwen2-1.5b [dense]: GQA + QKV bias (arXiv:2407.10671).

28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-1.5b", family="dense",
    num_layers=28, d_model=1536, num_heads=12, num_kv_heads=2,
    d_ff=8960, vocab_size=151_936, qkv_bias=True, tied_embeddings=True,
    rope_theta=1_000_000.0,
)

SMOKE = CONFIG.replace(
    num_layers=3, d_model=24, num_heads=4, num_kv_heads=2, head_dim=6,
    d_ff=48, vocab_size=199, dtype="float32", attn_chunk=8,
)
