"""recurrentgemma-9b [hybrid]: RG-LRU + local attn 1:2 (arXiv:2402.19427).

38L d_model=4096 16H (MQA kv=1) d_ff=12288 vocab=256000, window 2048.
38 = 12 x (R,R,A) superblocks + trailing (R,R).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b", family="hybrid",
    num_layers=38, d_model=4096, num_heads=16, num_kv_heads=1,
    d_ff=12288, vocab_size=256_000, head_dim=256,
    block_pattern=("R", "R", "A"), sliding_window=2048, lru_width=4096,
    tied_embeddings=True,
)

SMOKE = CONFIG.replace(
    num_layers=5, d_model=32, num_heads=4, num_kv_heads=1, head_dim=8,
    d_ff=64, vocab_size=199, sliding_window=8, lru_width=32,
    dtype="float32", attn_chunk=8,
)
