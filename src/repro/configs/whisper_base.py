"""whisper-base [audio]: enc-dec, conv frontend STUB (arXiv:2212.04356).

6L (encoder) + 6L (decoder) d_model=512 8H d_ff=2048 vocab=51865.
input_specs feeds precomputed frame embeddings (B, 1500, 512).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", family="audio",
    num_layers=6, d_model=512, num_heads=8, num_kv_heads=8,
    d_ff=2048, vocab_size=51_865,
    encoder_layers=6, decoder_layers=6, encoder_seq=1500, act="gelu",
    max_target_positions=40_960,
)

SMOKE = CONFIG.replace(
    num_layers=2, d_model=32, num_heads=4, num_kv_heads=4, head_dim=8,
    d_ff=64, vocab_size=199, encoder_layers=2, decoder_layers=2,
    encoder_seq=12, dtype="float32", attn_chunk=8, max_target_positions=64,
)
