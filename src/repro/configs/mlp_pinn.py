"""The paper's own model: tanh MLP 50 -> 768 -> 768 -> 512 -> 512 -> 1
(section 4 experimental setup), trained as a Poisson PINN with the
collapsed-Taylor Laplacian in the loss.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mlp-pinn", family="mlp",
    num_layers=5, d_model=768, num_heads=1, num_kv_heads=1,
    d_ff=0, vocab_size=0,
    mlp_sizes=(50, 768, 768, 512, 512, 1),
    dtype="float32", param_dtype="float32",
)

SMOKE = CONFIG.replace(mlp_sizes=(5, 32, 32, 1))
