"""xlstm-350m [ssm]: sLSTM + mLSTM blocks (arXiv:2405.04517).

24L d_model=1024 4H (kv=4) d_ff=0 (cells carry their own projections)
vocab=50304. Pattern (m,m,m,s) x 6.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m", family="ssm",
    num_layers=24, d_model=1024, num_heads=4, num_kv_heads=4,
    d_ff=0, vocab_size=50_304,
    block_pattern=("m", "m", "m", "s"),
)

SMOKE = CONFIG.replace(
    num_layers=4, d_model=16, num_heads=2, num_kv_heads=2, vocab_size=199,
    block_pattern=("m", "s"), dtype="float32",
)
