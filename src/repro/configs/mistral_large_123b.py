"""mistral-large-123b [dense] (hf:mistralai/Mistral-Large-Instruct-2407).

88L d_model=12288 96H (GQA kv=8) d_ff=28672 vocab=32768.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mistral-large-123b", family="dense",
    num_layers=88, d_model=12288, num_heads=96, num_kv_heads=8,
    d_ff=28672, vocab_size=32_768, head_dim=128, rope_theta=1_000_000.0,
)

SMOKE = CONFIG.replace(
    num_layers=3, d_model=32, num_heads=8, num_kv_heads=2, head_dim=4,
    d_ff=64, vocab_size=199, dtype="float32", attn_chunk=8,
)
