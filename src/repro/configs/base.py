"""Model configuration schema shared by all assigned architectures."""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm | mlp
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: Optional[int] = None
    qkv_bias: bool = False
    tied_embeddings: bool = False
    rope_theta: float = 10_000.0
    # rotary embeddings between the q/k projections and the score dot. LM
    # configs keep them on; differential-operator heads (transformer PINNs /
    # operator learning, which lift continuous coordinates and carry their
    # own positional lift) set False. Either way the collapsed-Taylor
    # offload planner fuses the whole block as ONE superblock kernel
    # (q/k/v/o projections + GQA attention, see repro.core.offload): the
    # jet-constant rotary tables — and qkv_bias projection biases — fold
    # into the kernel's projection stage.
    use_rope: bool = True
    norm_eps: float = 1e-6
    act: str = "silu"  # mlp activation: silu (swiglu) | gelu
    sliding_window: Optional[int] = None  # local attention window, None = full

    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0  # expert hidden dim (fine-grained experts)
    dense_residual_d_ff: int = 0  # arctic: parallel dense FFN
    first_dense_layers: int = 0  # deepseek-moe: first k layers dense
    capacity_factor: float = 1.25
    moe_seq_chunk: int = 8192  # dispatch long sequences in scanned chunks

    # --- hybrid / recurrent ---
    block_pattern: Tuple[str, ...] = ()  # e.g. ('R','R','A') griffin, ('m','m','m','s') xlstm
    rglru_conv_width: int = 4
    lru_width: Optional[int] = None

    # --- enc-dec (whisper) ---
    encoder_layers: int = 0
    decoder_layers: int = 0
    encoder_seq: int = 1500  # stubbed frame-embedding length

    # --- vlm ---
    cross_attn_every: int = 0  # insert a gated cross-attn layer every N layers
    vision_tokens: int = 6404  # stubbed patch-embedding count (4 tiles x 1601)
    vision_dim: int = 1280

    # --- mlp (the paper's model) ---
    mlp_sizes: Tuple[int, ...] = ()

    # --- numerics / execution ---
    dtype: str = "bfloat16"  # activation/compute dtype
    param_dtype: str = "float32"
    attn_chunk: int = 512  # kv-block size of the streaming-softmax attention
    # attention graph: 'flash' (scanned streaming softmax) | 'reference'
    # (canonical masked-softmax graph that collapsed-Taylor offload can fuse)
    attn_impl: str = "flash"
    remat: bool = True
    remat_policy: str = "nothing"  # nothing | dots (see distributed notes)
    use_pallas: bool = False  # TPU runtime: use Pallas kernels where available
    max_target_positions: int = 8192  # decoder position-embedding capacity

    vocab_pad_multiple: int = 128  # pad embeddings so vocab shards evenly

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return -(-self.vocab_size // m) * m if self.vocab_size else 0

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    @property
    def compute_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def params_dtype(self):
        return jnp.dtype(self.param_dtype)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell of the assignment."""

    name: str  # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}
