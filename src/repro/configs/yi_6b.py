"""yi-6b [dense]: llama-arch GQA (arXiv:2403.04652).

32L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="yi-6b", family="dense",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=4,
    d_ff=11008, vocab_size=64_000, rope_theta=5_000_000.0,
)

SMOKE = CONFIG.replace(
    num_layers=3, d_model=32, num_heads=4, num_kv_heads=2, head_dim=8,
    d_ff=64, vocab_size=199, dtype="float32", attn_chunk=8,
)
