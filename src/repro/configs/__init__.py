"""Assigned-architecture configs. ``get_config(name)`` returns the full
config; ``get_smoke_config(name)`` a reduced same-family config for CPU
smoke tests. ``ARCHS`` lists all selectable ``--arch`` ids."""

from importlib import import_module

from .base import SHAPES, ModelConfig, ShapeConfig  # noqa: F401

ARCHS = [
    "recurrentgemma-9b",
    "xlstm-350m",
    "mistral-large-123b",
    "yi-6b",
    "qwen2-1.5b",
    "llama3.2-3b",
    "deepseek-moe-16b",
    "arctic-480b",
    "whisper-base",
    "llama3.2-vision-90b",
    "mlp-pinn",  # the paper's own model (11th config)
]

_MODULES = {a: a.replace("-", "_").replace(".", "_") for a in ARCHS}


def get_config(name: str) -> ModelConfig:
    return import_module(f"repro.configs.{_MODULES[name]}").CONFIG


def get_smoke_config(name: str) -> ModelConfig:
    return import_module(f"repro.configs.{_MODULES[name]}").SMOKE
