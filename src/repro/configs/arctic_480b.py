"""arctic-480b [moe]: 128-expert top-2 MoE + dense residual branch
(hf:Snowflake/snowflake-arctic-base).

35L d_model=7168 56H (GQA kv=8) expert d_ff=4864 vocab=32000; the dense
residual runs in parallel with the MoE FFN (dense-MoE hybrid).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b", family="moe",
    num_layers=35, d_model=7168, num_heads=56, num_kv_heads=8,
    d_ff=4864, vocab_size=32_000,
    num_experts=128, experts_per_token=2, moe_d_ff=4864,
    dense_residual_d_ff=14_336,
)

SMOKE = CONFIG.replace(
    num_layers=2, d_model=32, num_heads=4, num_kv_heads=2, head_dim=8,
    d_ff=64, vocab_size=199, num_experts=8, experts_per_token=2,
    moe_d_ff=16, dense_residual_d_ff=32, capacity_factor=4.0,
    dtype="float32", attn_chunk=8,
)
