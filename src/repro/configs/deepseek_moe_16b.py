"""deepseek-moe-16b [moe]: 2 shared + 64 routed top-6 fine-grained experts,
first layer dense (arXiv:2401.06066).

28L d_model=2048 16H (kv=16, MHA) expert d_ff=1408 vocab=102400; dense
layer d_ff = 4 * 2048 * 1.34 ~ 10944 (deepseek uses 10944).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b", family="moe",
    num_layers=28, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=10944, vocab_size=102_400,
    num_experts=64, experts_per_token=6, num_shared_experts=2,
    moe_d_ff=1408, first_dense_layers=1,
)

SMOKE = CONFIG.replace(
    num_layers=3, d_model=32, num_heads=4, num_kv_heads=4, head_dim=8,
    d_ff=64, vocab_size=199, num_experts=8, experts_per_token=2,
    num_shared_experts=1, moe_d_ff=16, capacity_factor=4.0,
    dtype="float32", attn_chunk=8,
)
