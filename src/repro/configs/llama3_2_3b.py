"""llama3.2-3b [dense] (hf:meta-llama/Llama-3.2-3B).

28L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=128256.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-3b", family="dense",
    num_layers=28, d_model=3072, num_heads=24, num_kv_heads=8,
    d_ff=8192, vocab_size=128_256, tied_embeddings=True,
    rope_theta=500_000.0,
)

SMOKE = CONFIG.replace(
    num_layers=3, d_model=32, num_heads=4, num_kv_heads=2, head_dim=8,
    d_ff=64, vocab_size=199, dtype="float32", attn_chunk=8,
)
